//! Parse-tree permutation map — paper §4.2.2 with the supplement §B.2
//! counter action (the scheme the paper's experiments use).
//!
//! A counter τ walks the p-dimensional index space while a sliding window
//! of size δ = 1 reads the unnormalised tessellating vector ã:
//!
//! ```text
//!   τ_j = k·j          if ã^j = +1
//!   τ_j = τ_{j-1} + 1  if ã^j =  0
//!   τ_j = k·(k + j)    if ã^j = -1        (j = 1 … k, τ_0 = 0)
//! ```
//!
//! The +1/-1 anchors jump to coordinate-specific bases while runs of zeros
//! advance sequentially from the last anchor, so two factors share slot
//! τ_j iff their tessellating vectors agree on the whole suffix
//! `[a^{j-t}, …, a^j]` back to the most recent anchor — the supplement's
//! "no accidental overlap" desideratum with t₀ ≥ δ. Dimensionality is
//! p ~ O(k²) but only k slots are occupied, and with the inverted-index
//! representation storage stays O(k log p) per factor.
//!
//! D-ary grids are handled by anchoring each non-zero level ℓ ∈ [-D, D]
//! at base `k·((D + ℓ)·k̂ + j)` for a level-specific block (exactly the
//! ternary rule when D = 1, since levels ±1 give blocks 0 and 2k̂).

use super::PermutationMap;
use crate::tessellation::TessVector;

/// Parse-tree (counter) permutation map.
#[derive(Clone, Debug)]
pub struct ParseTree {
    k: usize,
    d: u32,
}

impl ParseTree {
    /// Map for k-dim factors on a D-grid (D = 1 is the paper's scheme).
    pub fn new(k: usize, d: u32) -> Self {
        assert!(k > 0 && d >= 1);
        ParseTree { k, d }
    }

    /// Level-block base for anchor level `l` (non-zero) at 1-indexed j.
    #[inline]
    fn anchor(&self, level: i16, j: usize) -> u32 {
        debug_assert!(level != 0);
        let k = self.k as u32;
        // blocks indexed by (D + level) ∈ {0..2D} \ {D}; block b starts at
        // b·k² and anchor j within a block is b·k² + k·j.
        let block = (self.d as i32 + level as i32) as u32;
        block * k * k + k * j as u32
    }
}

impl PermutationMap for ParseTree {
    fn p(&self) -> usize {
        // max anchor: block 2D at j = k → 2D·k² + k²  = (2D+1)k²; zero runs
        // after it add < k, so (2D+1)k² + k + 1 bounds every index.
        let k = self.k;
        (2 * self.d as usize + 1) * k * k + k + 1
    }

    fn index_map(&self, tess: &TessVector) -> Vec<u32> {
        assert_eq!(tess.levels.len(), self.k, "tess k mismatch");
        assert_eq!(tess.d, self.d, "tess grid mismatch");
        let mut out = Vec::with_capacity(self.k);
        let mut tau = 0u32; // τ_0
        for (j0, &level) in tess.levels.iter().enumerate() {
            let j = j0 + 1; // paper is 1-indexed
            tau = if level == 0 { tau + 1 } else { self.anchor(level, j) };
            out.push(tau);
        }
        out
    }

    fn name(&self) -> &'static str {
        "parse-tree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::permutation::is_injective;
    use crate::tessellation::{DaryTessellation, TernaryTessellation, Tessellation};
    use crate::testing::prop;

    fn tv(levels: Vec<i16>) -> TessVector {
        TessVector { levels, d: 1 }
    }

    #[test]
    fn matches_supplement_recurrence() {
        // k = 4, ã = [1, 0, 0, -1]:
        // τ1 = k·1 = 4, τ2 = 5, τ3 = 6, τ4 = k(k+4) = 32... with block form:
        // level -1 → block 0? No: D=1, block = 1 + (-1) = 0 → 0·k² + k·j = k·j?
        // That would collide with the +1 anchors. See block assignment:
        // +1 → block 2 (2k² + kj), 0 run, -1 → block 0 (kj).
        // The supplement's literal rule (kj for +1, k(k+j) for -1) is the
        // same map with blocks swapped — a relabelling of slots, which
        // preserves every overlap property.
        let pt = ParseTree::new(4, 1);
        let m = pt.index_map(&tv(vec![1, 0, 0, -1]));
        // +1 at j=1: block 2 → 2·16 + 4 = 36; zeros: 37, 38; -1 at j=4:
        // block 0 → 0 + 16 = 16.
        assert_eq!(m, vec![36, 37, 38, 16]);
    }

    #[test]
    fn p_bound_holds() {
        prop(100, |g| {
            let k = g.usize_in(1..=32);
            let d = *g.choose(&[1u32, 2, 8]);
            let z = g.vec_gaussian(k..=k);
            let tess = DaryTessellation::new(k, d).assign(&z);
            let pt = ParseTree::new(k, d);
            let m = pt.index_map(&tess);
            assert!(m.iter().all(|&i| (i as usize) < pt.p()));
        });
    }

    #[test]
    fn injective_within_vector() {
        prop(150, |g| {
            let k = g.usize_in(2..=32);
            let z = g.vec_gaussian(k..=k);
            let tess = TernaryTessellation::new(k).assign(&z);
            let m = ParseTree::new(k, 1).index_map(&tess);
            assert!(is_injective(&m), "collision in {m:?} for {:?}", tess.levels);
        });
    }

    #[test]
    fn overlap_iff_suffix_agrees() {
        // τ_j = τ'_j ⇔ ã agrees on [last-anchor..j] — verify the ⇔ against
        // a direct suffix comparison.
        prop(150, |g| {
            let k = g.usize_in(2..=12);
            let tess = TernaryTessellation::new(k);
            let a1 = tess.assign(&g.unit_vector(k));
            let a2 = tess.assign(&g.unit_vector(k));
            let pt = ParseTree::new(k, 1);
            let m1 = pt.index_map(&a1);
            let m2 = pt.index_map(&a2);
            for j in 0..k {
                // suffix back to the most recent non-zero (anchor) in a1
                let mut anchor = j;
                while anchor > 0 && a1.levels[anchor] == 0 {
                    anchor -= 1;
                }
                let same_suffix = a1.levels[anchor..=j] == a2.levels[anchor..=j]
                    // anchor structure must line up too: a2 must not have a
                    // later anchor inside the window
                    && (anchor == 0
                        || a2.levels[anchor] != 0
                        || a1.levels[anchor] != 0);
                let agree = m1[j] == m2[j];
                if same_suffix && a1.levels[anchor] != 0 {
                    assert!(agree, "suffix agreed but slots differ at {j}");
                }
                if agree {
                    // slots equal ⇒ levels along the suffix equal
                    assert_eq!(
                        a1.levels[anchor..=j],
                        a2.levels[anchor..=j],
                        "slots equal but suffixes differ at {j}"
                    );
                }
            }
        });
    }

    #[test]
    fn zero_prefix_walks_from_origin() {
        // leading zeros count up from τ_0 = 0; the +1 anchor at j = 3 jumps
        // to its block-2 base (2k² + k·j = 44, see matches_supplement_
        // recurrence for the block relabelling) and the trailing zero
        // resumes the walk from there.
        let pt = ParseTree::new(4, 1);
        let m = pt.index_map(&tv(vec![0, 0, 1, 0]));
        assert_eq!(m, vec![1, 2, 44, 45]);
    }

    #[test]
    fn anchors_are_coordinate_unique() {
        // the possible τ_j for coordinate j depend only on j (supplement
        // B.2): anchors are {k·j, 2k²+k·j} plus zero-runs; check two
        // different vectors can't put *different* coordinates in one slot.
        prop(100, |g| {
            let k = g.usize_in(2..=10);
            let tess = TernaryTessellation::new(k);
            let a1 = tess.assign(&g.unit_vector(k));
            let a2 = tess.assign(&g.unit_vector(k));
            let pt = ParseTree::new(k, 1);
            let m1 = pt.index_map(&a1);
            let m2 = pt.index_map(&a2);
            for (j1, &s1) in m1.iter().enumerate() {
                for (j2, &s2) in m2.iter().enumerate() {
                    if s1 == s2 {
                        assert_eq!(j1, j2, "slot {s1} shared across coordinates");
                    }
                }
            }
        });
    }

    #[test]
    fn dary_parse_tree_valid() {
        prop(80, |g| {
            let k = g.usize_in(2..=16);
            let d = *g.choose(&[2u32, 4]);
            let z = g.vec_gaussian(k..=k);
            let tess = DaryTessellation::new(k, d).assign(&z);
            let pt = ParseTree::new(k, d);
            let m = pt.index_map(&tess);
            assert!(is_injective(&m));
            assert!(m.iter().all(|&i| (i as usize) < pt.p()));
        });
    }
}
