//! General sliding-window parse-tree map — paper §4.2.2 with window size
//! δ ≥ 1 (the δ = 1 case is exactly [`ParseTree`](super::ParseTree)).
//!
//! At step j the counter action reads the last δ levels of the
//! unnormalised tessellating vector, `ã_δ^j = [ã^{j-δ+1}, …, ã^j]`
//! (out-of-range positions read as level 0, matching the paper's
//! "initialise by mapping the first δ−1 coordinates" convention), and
//! jumps to a window-specific anchor when the current level is non-zero:
//!
//! ```text
//!   block(j) = Σ_{i=0}^{δ-1} (ã^{j-i} + D) · (2D+1)^i
//!   τ_j = block(j)·k² + k·j    if ã^j ≠ 0        (anchor)
//!   τ_j = τ_{j-1} + 1          if ã^j = 0        (zero-run)
//! ```
//!
//! Two factors share slot τ_j iff their tessellating vectors agree on the
//! whole window (anchor case) or on the suffix back to the most recent
//! anchor (zero-run case) — the supplement's desideratum with t₀ ≥ δ.
//! Larger δ suppresses more "accidental" overlap at the cost of a larger
//! index space, `p = (2D+1)^δ·k² + k + 1`; occupied slots stay at k per
//! factor, so inverted-index storage is unchanged.

use super::PermutationMap;
use crate::tessellation::TessVector;

/// δ-window parse-tree permutation map.
#[derive(Clone, Debug)]
pub struct ParseTreeDelta {
    k: usize,
    d: u32,
    delta: usize,
}

impl ParseTreeDelta {
    /// Map for k-dim factors on a D-grid with window size `delta ≥ 1`.
    ///
    /// Panics if the block space `(2D+1)^δ·k²` overflows `u32` (the index
    /// type of the sparse embeddings) — δ is a small constant in practice
    /// (the paper uses δ = 1).
    pub fn new(k: usize, d: u32, delta: usize) -> Self {
        assert!(k > 0 && d >= 1 && delta >= 1);
        let base = (2 * d as u64 + 1).checked_pow(delta as u32).expect("δ too large");
        let p = base * (k as u64) * (k as u64) + k as u64 + 1;
        assert!(p <= u32::MAX as u64, "index space exceeds u32: δ={delta}");
        ParseTreeDelta { k, d, delta }
    }

    /// Window size δ.
    pub fn delta(&self) -> usize {
        self.delta
    }

    /// Block id for the window ending at 0-indexed position `j0`.
    #[inline]
    fn block(&self, levels: &[i16], j0: usize) -> u64 {
        let base = 2 * self.d as u64 + 1;
        let mut b = 0u64;
        // most recent level is the lowest digit (i = 0)
        for i in 0..self.delta {
            let lev = if j0 >= i { levels[j0 - i] } else { 0 };
            let digit = (self.d as i64 + lev as i64) as u64;
            b += digit * base.pow(i as u32);
        }
        b
    }
}

impl PermutationMap for ParseTreeDelta {
    fn p(&self) -> usize {
        let base = (2 * self.d as usize + 1).pow(self.delta as u32);
        base * self.k * self.k + self.k + 1
    }

    fn index_map(&self, tess: &TessVector) -> Vec<u32> {
        assert_eq!(tess.levels.len(), self.k, "tess k mismatch");
        assert_eq!(tess.d, self.d, "tess grid mismatch");
        let k = self.k as u64;
        let mut out = Vec::with_capacity(self.k);
        let mut tau = 0u64; // τ_0
        for (j0, &level) in tess.levels.iter().enumerate() {
            let j = (j0 + 1) as u64; // paper is 1-indexed
            tau = if level == 0 {
                tau + 1
            } else {
                self.block(&tess.levels, j0) * k * k + k * j
            };
            out.push(tau as u32);
        }
        out
    }

    fn name(&self) -> &'static str {
        "parse-tree-delta"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::permutation::{is_injective, ParseTree};
    use crate::tessellation::{Tessellation, TernaryTessellation};
    use crate::testing::prop;

    fn tv(levels: Vec<i16>) -> TessVector {
        TessVector { levels, d: 1 }
    }

    #[test]
    fn delta_one_equals_parse_tree() {
        prop(100, |g| {
            let k = g.usize_in(2..=16);
            let tess = TernaryTessellation::new(k).assign(&g.unit_vector(k));
            let a = ParseTree::new(k, 1).index_map(&tess);
            let b = ParseTreeDelta::new(k, 1, 1).index_map(&tess);
            assert_eq!(a, b);
        });
    }

    #[test]
    fn maps_are_injective() {
        prop(100, |g| {
            let k = g.usize_in(2..=12);
            let delta = g.usize_in(1..=3);
            let tess = TernaryTessellation::new(k).assign(&g.unit_vector(k));
            let pt = ParseTreeDelta::new(k, 1, delta);
            let m = pt.index_map(&tess);
            assert!(is_injective(&m), "δ={delta} map {m:?}");
            assert!(m.iter().all(|&i| (i as usize) < pt.p()));
        });
    }

    #[test]
    fn window_agreement_governs_slot_sharing() {
        // anchor slots agree iff the δ-windows agree (paper's t₀ ≥ δ).
        let k = 6;
        let pt = ParseTreeDelta::new(k, 1, 2);
        let a = tv(vec![1, 1, 0, -1, 0, 1]);
        let b = tv(vec![0, 1, 0, -1, 0, 1]); // differs at coord 0 only
        let (ma, mb) = (pt.index_map(&a), pt.index_map(&b));
        // coord 1: window (a^0, a^1) differs -> different slots under δ=2
        assert_ne!(ma[1], mb[1]);
        // coord 3 anchor: window (a^2, a^3) = (0, -1) identical -> shared
        assert_eq!(ma[3], mb[3]);
        // under δ=1 coord 1 WOULD share (same level +1 at same position)
        let pt1 = ParseTreeDelta::new(k, 1, 1);
        assert_eq!(pt1.index_map(&a)[1], pt1.index_map(&b)[1]);
    }

    #[test]
    fn larger_delta_shares_fewer_slots() {
        // across random pairs, the number of shared anchor slots is
        // non-increasing in δ (longer suffixes must agree).
        let k = 12;
        let tess = TernaryTessellation::new(k);
        let shared = std::sync::Mutex::new([0usize; 3]);
        prop(200, |g| {
            let z1 = g.unit_vector(k);
            let z2 = g.unit_vector(k);
            let (a1, a2) = (tess.assign(&z1), tess.assign(&z2));
            for (di, delta) in [1usize, 2, 3].into_iter().enumerate() {
                let pt = ParseTreeDelta::new(k, 1, delta);
                let (m1, m2) = (pt.index_map(&a1), pt.index_map(&a2));
                let s = m1.iter().filter(|i| m2.contains(i)).count();
                shared.lock().unwrap()[di] += s;
            }
        });
        let shared = shared.into_inner().unwrap();
        assert!(
            shared[0] >= shared[1] && shared[1] >= shared[2],
            "sharing must not increase with δ: {shared:?}"
        );
        assert!(shared[0] > 0, "δ=1 must share something over 200 pairs");
    }

    #[test]
    fn zero_runs_walk_from_anchor() {
        let pt = ParseTreeDelta::new(5, 1, 2);
        let m = pt.index_map(&tv(vec![0, 1, 0, 0, 0]));
        // prefix zero: τ_1 = 1; anchor at j=2; then run +1 each
        assert_eq!(m[0], 1);
        assert_eq!(m[2], m[1] + 1);
        assert_eq!(m[3], m[1] + 2);
        assert_eq!(m[4], m[1] + 3);
    }

    #[test]
    #[should_panic(expected = "index space exceeds u32")]
    fn oversized_delta_rejected() {
        let _ = ParseTreeDelta::new(1000, 8, 6);
    }
}
