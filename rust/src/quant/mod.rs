//! Compressed serving tier: int8 quantized factors + bit-packed postings.
//!
//! At the ROADMAP's millions-of-users scale the ceiling is bytes, not
//! arithmetic: every item factor as f32 and every posting as a raw u32
//! dominate resident memory. This subsystem shrinks both axes while
//! keeping the paper's prune → exact-rescore contract intact:
//!
//! * [`QuantizedFactorStore`] — symmetric per-item int8 scalar
//!   quantization with stored scales and a fixed-point i8×i8→i32 dot
//!   kernel ([`dot_i8`]) for candidate rescoring. The engine re-ranks
//!   the top `refine · κ` quantized survivors with exact f32 inner
//!   products, so accuracy loss is bounded by the item quantization
//!   error (≈ 0.4 % of ‖u‖‖v‖ at int8; `docs/QUANT.md` derives the
//!   bound and reports measured recall).
//! * [`PackedPostings`] — delta-encoded, block bit-packed posting lists
//!   ([`BLOCK`]-entry blocks with per-block max-id skip entries), the
//!   alternative arena behind `InvertedIndex`, decoded block-at-a-time
//!   into the query scratch.
//!
//! Both are selected by config (`configx::QuantMode` /
//! `configx::PostingsMode`, CLI `--quant` / `--postings`), persist in
//! `GSNP` snapshots as format-v2 sections, and report their true
//! residency through `SourceStats`. `benches/quant_tier.rs` measures
//! the memory / recall / throughput trade on both workloads.

mod packed;
mod store;

pub use packed::{PackedPostings, BLOCK};
pub use store::{dot_i8, quantize_into, QuantizedFactorStore};
