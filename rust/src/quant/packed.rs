//! Delta-encoded, block bit-packed posting lists.
//!
//! Posting lists are strictly increasing item ids (each item contributes
//! a dimension at most once), which makes them a textbook fit for
//! delta + bit-packing: store each list as blocks of up to [`BLOCK`]
//! ids, where a block keeps its first id verbatim and packs the
//! remaining `count - 1` gaps (`id[i] - id[i-1] - 1`) at the block's
//! fixed bit width — the width of the largest gap in that block. Dense
//! lists (small gaps) compress toward ~1–6 bits per posting instead of
//! 32; a per-block *max-id* skip entry lets future intersection-style
//! consumers skip blocks without decoding them.
//!
//! Decoding is block-at-a-time into a reusable scratch buffer, so the
//! query hot path touches one small buffer plus the packed words —
//! scan-friendly, no per-posting branching beyond the bit cursor.
//!
//! The struct is a plain bundle of flat `u32` arenas, so the snapshot
//! codec serialises it verbatim and [`PackedPostings::from_parts`]
//! revalidates everything (including a full decode pass) on load.

use crate::error::{GeomapError, Result};

/// Ids per block (the last block of a list may be shorter).
pub const BLOCK: usize = 128;

/// Bit-packed posting arena over `p` dimensions (see module docs).
#[derive(Clone)]
pub struct PackedPostings {
    /// Ambient dimension count p.
    p: usize,
    /// Id space: every decoded id is `< items`.
    items: usize,
    /// Total postings across all dimensions.
    total: usize,
    /// Per-dimension block range: dimension `d` owns blocks
    /// `dim_offsets[d] .. dim_offsets[d + 1]` (len = p + 1, monotone).
    dim_offsets: Vec<u32>,
    /// Per-block start word in `words`.
    block_words: Vec<u32>,
    /// Per-block first id (stored verbatim, not packed).
    block_first: Vec<u32>,
    /// Per-block max id — the skip entry (last id; lists are ascending).
    block_max: Vec<u32>,
    /// Per-block `count | width << 16` (count ≤ BLOCK, width ≤ 32).
    block_info: Vec<u32>,
    /// Gap bits, little-endian within each u32, LSB first. Every block
    /// starts on a fresh word.
    words: Vec<u32>,
}

fn bits_for(gap: u32) -> u32 {
    32 - gap.leading_zeros()
}

impl PackedPostings {
    /// Pack per-dimension posting lists. `lists(d)` must yield strictly
    /// increasing ids `< items` for every `d < p` (the raw CSR arena
    /// guarantees this; debug-asserted here).
    pub fn pack<'a, F>(p: usize, items: usize, lists: F) -> PackedPostings
    where
        F: Fn(usize) -> &'a [u32],
    {
        let mut pk = PackedPostings {
            p,
            items,
            total: 0,
            dim_offsets: Vec::with_capacity(p + 1),
            block_words: Vec::new(),
            block_first: Vec::new(),
            block_max: Vec::new(),
            block_info: Vec::new(),
            words: Vec::new(),
        };
        pk.dim_offsets.push(0);
        for d in 0..p {
            let list = lists(d);
            debug_assert!(list.windows(2).all(|w| w[0] < w[1]));
            pk.total += list.len();
            for chunk in list.chunks(BLOCK) {
                pk.push_block(chunk);
            }
            pk.dim_offsets.push(pk.block_first.len() as u32);
        }
        pk
    }

    fn push_block(&mut self, ids: &[u32]) {
        debug_assert!(!ids.is_empty() && ids.len() <= BLOCK);
        let width = ids
            .windows(2)
            .map(|w| bits_for(w[1] - w[0] - 1))
            .max()
            .unwrap_or(0);
        self.block_words.push(self.words.len() as u32);
        self.block_first.push(ids[0]);
        self.block_max.push(*ids.last().unwrap());
        self.block_info.push(ids.len() as u32 | (width << 16));
        if width == 0 {
            return; // a consecutive run packs to zero gap bits
        }
        let mut acc = 0u64;
        let mut used = 0u32;
        for w in ids.windows(2) {
            let gap = w[1] - w[0] - 1;
            acc |= (gap as u64) << used;
            used += width;
            while used >= 32 {
                self.words.push(acc as u32);
                acc >>= 32;
                used -= 32;
            }
        }
        if used > 0 {
            self.words.push(acc as u32);
        }
    }

    /// Ambient dimension count p.
    pub fn dims(&self) -> usize {
        self.p
    }

    /// Id space bound (decoded ids are `< items`).
    pub fn items(&self) -> usize {
        self.items
    }

    /// Total postings stored.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Number of blocks.
    pub fn blocks(&self) -> usize {
        self.block_first.len()
    }

    /// Block index range of dimension `d`.
    #[inline]
    pub fn dim_blocks(&self, d: usize) -> std::ops::Range<usize> {
        self.dim_offsets[d] as usize..self.dim_offsets[d + 1] as usize
    }

    /// Posting count of dimension `d` (sums block counts, no decode).
    pub fn dim_len(&self, d: usize) -> usize {
        self.dim_blocks(d)
            .map(|b| (self.block_info[b] & 0xFFFF) as usize)
            .sum()
    }

    /// Max id of block `b` — the skip entry (no decode needed).
    #[inline]
    pub fn block_max(&self, b: usize) -> u32 {
        self.block_max[b]
    }

    /// Decode block `b` into `out` (cleared first; at most [`BLOCK`] ids,
    /// strictly increasing). Resolves the active kernel table per call;
    /// block-streaming loops resolve once and use
    /// [`decode_block_with`](Self::decode_block_with).
    #[inline]
    pub fn decode_block(&self, b: usize, out: &mut Vec<u32>) {
        self.decode_block_with(crate::kernels::active(), b, out)
    }

    /// [`decode_block`](Self::decode_block) with a caller-resolved
    /// kernel table ([`crate::kernels::active`], or a pinned arm in the
    /// equivalence tests and benches). Every arm decodes identically.
    #[inline]
    pub fn decode_block_with(
        &self,
        kern: &crate::kernels::Kernels,
        b: usize,
        out: &mut Vec<u32>,
    ) {
        out.clear();
        let info = self.block_info[b];
        let count = (info & 0xFFFF) as usize;
        let width = info >> 16;
        let mut id = self.block_first[b];
        out.push(id);
        // wrapping arithmetic (in every kernel arm): on well-formed data
        // nothing wraps; on a corrupt arena a wrapped id breaks the
        // strictly-increasing order that `from_parts` verifies, instead
        // of panicking here
        if width == 0 {
            // consecutive run
            for _ in 1..count {
                id = id.wrapping_add(1);
                out.push(id);
            }
            return;
        }
        (kern.unpack_deltas)(
            &self.words,
            self.block_words[b] as usize,
            width,
            count,
            id,
            out,
        );
    }

    /// Decode the full posting list of dimension `d`, appending to `out`.
    pub fn decode_dim(&self, d: usize, out: &mut Vec<u32>) {
        let mut block = Vec::with_capacity(BLOCK);
        for b in self.dim_blocks(d) {
            self.decode_block(b, &mut block);
            out.extend_from_slice(&block);
        }
    }

    /// Resident bytes of the packed arenas.
    pub fn memory_bytes(&self) -> usize {
        (self.dim_offsets.len()
            + self.block_words.len()
            + self.block_first.len()
            + self.block_max.len()
            + self.block_info.len()
            + self.words.len())
            * 4
    }

    /// The flat arenas, for the snapshot codec: `(dim_offsets,
    /// block_words, block_first, block_max, block_info, words)`.
    #[allow(clippy::type_complexity)]
    pub fn arenas(
        &self,
    ) -> (&[u32], &[u32], &[u32], &[u32], &[u32], &[u32]) {
        (
            &self.dim_offsets,
            &self.block_words,
            &self.block_first,
            &self.block_max,
            &self.block_info,
            &self.words,
        )
    }

    /// Reassemble from raw arenas (the snapshot load path). Everything a
    /// decode trusts is validated — block ranges, counts, widths, word
    /// bounds — and a full decode pass checks every id is in range,
    /// every list strictly increasing, and the skip entries honest; a
    /// corrupt section fails here instead of panicking at query time.
    pub fn from_parts(
        p: usize,
        items: usize,
        total: usize,
        dim_offsets: Vec<u32>,
        block_words: Vec<u32>,
        block_first: Vec<u32>,
        block_max: Vec<u32>,
        block_info: Vec<u32>,
        words: Vec<u32>,
    ) -> Result<PackedPostings> {
        let n_blocks = block_first.len();
        if dim_offsets.len() != p + 1 {
            return Err(GeomapError::Artifact(format!(
                "packed postings: dim offsets len {} != p + 1 = {}",
                dim_offsets.len(),
                p + 1
            )));
        }
        if dim_offsets.first() != Some(&0)
            || *dim_offsets.last().unwrap() as usize != n_blocks
            || dim_offsets.windows(2).any(|w| w[0] > w[1])
        {
            return Err(GeomapError::Artifact(
                "packed postings: dim offsets are not a monotone span of \
                 the block table"
                    .into(),
            ));
        }
        if block_words.len() != n_blocks
            || block_max.len() != n_blocks
            || block_info.len() != n_blocks
        {
            return Err(GeomapError::Artifact(
                "packed postings: block arenas disagree in length".into(),
            ));
        }
        let pk = PackedPostings {
            p,
            items,
            total,
            dim_offsets,
            block_words,
            block_first,
            block_max,
            block_info,
            words,
        };
        // structural bounds first, so the decode pass cannot panic
        for b in 0..n_blocks {
            let info = pk.block_info[b];
            let count = (info & 0xFFFF) as usize;
            let width = info >> 16;
            if count == 0 || count > BLOCK {
                return Err(GeomapError::Artifact(format!(
                    "packed postings: block {b} count {count} outside \
                     1..={BLOCK}"
                )));
            }
            if width > 32 {
                return Err(GeomapError::Artifact(format!(
                    "packed postings: block {b} gap width {width} > 32"
                )));
            }
            let gap_bits = (count - 1) as u64 * width as u64;
            let need_words = gap_bits.div_ceil(32);
            let start = pk.block_words[b] as u64;
            if start + need_words > pk.words.len() as u64 {
                return Err(GeomapError::Artifact(format!(
                    "packed postings: block {b} overruns the word arena"
                )));
            }
        }
        // full decode verification: id bounds, order, skip entries, total
        let mut decoded = 0usize;
        let mut buf = Vec::with_capacity(BLOCK);
        for d in 0..p {
            let mut prev: Option<u32> = None;
            for b in pk.dim_blocks(d) {
                pk.decode_block(b, &mut buf);
                decoded += buf.len();
                if *buf.last().unwrap() != pk.block_max[b] {
                    return Err(GeomapError::Artifact(format!(
                        "packed postings: block {b} skip entry disagrees \
                         with its decoded ids"
                    )));
                }
                for &id in &buf {
                    if prev.is_some_and(|p| p >= id) {
                        return Err(GeomapError::Artifact(format!(
                            "packed postings: dim {d} ids not strictly \
                             increasing"
                        )));
                    }
                    if id as usize >= items {
                        return Err(GeomapError::Artifact(format!(
                            "packed postings: id {id} >= item bound {items}"
                        )));
                    }
                    prev = Some(id);
                }
            }
        }
        if decoded != total {
            return Err(GeomapError::Artifact(format!(
                "packed postings: decoded {decoded} postings but header \
                 claims {total}"
            )));
        }
        Ok(pk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn pack_lists(items: usize, lists: &[Vec<u32>]) -> PackedPostings {
        PackedPostings::pack(lists.len(), items, |d| &lists[d])
    }

    fn decode_all(pk: &PackedPostings) -> Vec<Vec<u32>> {
        (0..pk.dims())
            .map(|d| {
                let mut out = Vec::new();
                pk.decode_dim(d, &mut out);
                out
            })
            .collect()
    }

    #[test]
    fn roundtrip_simple_lists() {
        let lists = vec![
            vec![0, 1, 2, 3],          // consecutive run: zero-width block
            vec![5],                   // singleton
            vec![],                    // empty dimension
            vec![0, 100, 101, 9_999],  // mixed gaps
        ];
        let pk = pack_lists(10_000, &lists);
        assert_eq!(pk.total(), 9);
        assert_eq!(decode_all(&pk), lists);
        assert_eq!(pk.dim_len(0), 4);
        assert_eq!(pk.dim_len(2), 0);
        assert_eq!(pk.dim_len(3), 4);
    }

    #[test]
    fn multi_block_lists_roundtrip() {
        // spans several blocks, including an exact BLOCK boundary
        let mut rng = Rng::seeded(7);
        for n in [BLOCK - 1, BLOCK, BLOCK + 1, 3 * BLOCK + 17] {
            let mut ids: Vec<u32> = Vec::new();
            let mut cur = 0u32;
            for _ in 0..n {
                cur += 1 + (rng.below(50) as u32);
                ids.push(cur);
            }
            let lists = vec![ids.clone()];
            let pk = pack_lists(cur as usize + 1, &lists);
            assert_eq!(decode_all(&pk), lists, "n={n}");
            let blocks = pk.dim_blocks(0);
            assert_eq!(blocks.len(), n.div_ceil(BLOCK));
            // skip entries are the true block maxima
            for b in pk.dim_blocks(0) {
                let mut buf = Vec::new();
                pk.decode_block(b, &mut buf);
                assert_eq!(pk.block_max(b), *buf.last().unwrap());
                assert!(buf.len() <= BLOCK);
            }
        }
    }

    #[test]
    fn wide_gaps_need_full_width() {
        // gap of u32::MAX - 1 forces a 32-bit width
        let lists = vec![vec![0u32, u32::MAX]];
        let pk = pack_lists(usize::MAX, &lists);
        assert_eq!(decode_all(&pk), lists);
    }

    #[test]
    fn random_lists_property() {
        let mut rng = Rng::seeded(42);
        for _ in 0..30 {
            let p = 1 + rng.below(8);
            let items = 2 + rng.below(5000);
            let mut lists = Vec::with_capacity(p);
            for _ in 0..p {
                let mut set: Vec<u32> = (0..items as u32)
                    .filter(|_| rng.below(4) == 0)
                    .collect();
                set.dedup();
                lists.push(set);
            }
            let pk = pack_lists(items, &lists);
            assert_eq!(decode_all(&pk), lists);
            assert_eq!(
                pk.total(),
                lists.iter().map(Vec::len).sum::<usize>()
            );
        }
    }

    #[test]
    fn packed_is_smaller_than_raw_on_dense_lists() {
        // every other id present: gaps of 1 → 1-bit packing
        let ids: Vec<u32> = (0..20_000u32).step_by(2).collect();
        let lists = vec![ids];
        let pk = pack_lists(20_000, &lists);
        let raw_bytes = lists[0].len() * 4;
        assert!(
            pk.memory_bytes() * 4 < raw_bytes,
            "packed {} vs raw {raw_bytes}",
            pk.memory_bytes()
        );
    }

    // -- adversarial decode coverage (ISSUE 4 satellite): every corrupt
    // -- arena must come back as `Err` from `from_parts`, never panic,
    // -- so the traversal path only ever walks verified blocks.

    #[test]
    fn truncated_final_block_rejected() {
        // gaps of 2 → nonzero width → the word arena carries real bits;
        // chopping its tail makes the last block overrun it
        let ids: Vec<u32> = (0..300u32).map(|i| i * 3).collect();
        let lists = vec![ids];
        let pk = pack_lists(1000, &lists);
        let (dofs, bw, bf, bm, bi, w) = pk.arenas();
        assert!(!w.is_empty());
        for cut in 1..=w.len().min(3) {
            let truncated = w[..w.len() - cut].to_vec();
            let r = PackedPostings::from_parts(
                1,
                1000,
                pk.total(),
                dofs.to_vec(),
                bw.to_vec(),
                bf.to_vec(),
                bm.to_vec(),
                bi.to_vec(),
                truncated,
            );
            assert!(r.is_err(), "cut of {cut} words must be rejected");
        }
    }

    #[test]
    fn skip_entry_lying_low_or_high_rejected() {
        // the per-block max-id skip entry must agree with the decoded
        // ids exactly — one off in either direction is a corrupt arena
        let lists = vec![vec![5u32, 9, 40, 200]];
        let pk = pack_lists(300, &lists);
        let (dofs, bw, bf, bm, bi, w) = pk.arenas();
        for delta in [-1i64, 1] {
            let mut bad = bm.to_vec();
            bad[0] = (bad[0] as i64 + delta) as u32;
            let r = PackedPostings::from_parts(
                1,
                300,
                pk.total(),
                dofs.to_vec(),
                bw.to_vec(),
                bf.to_vec(),
                bad,
                bi.to_vec(),
                w.to_vec(),
            );
            assert!(r.is_err(), "skip entry lying by {delta} must fail");
        }
    }

    #[test]
    fn zero_width_blocks_roundtrip_and_reject_corrupt_counts() {
        // a consecutive run packs to zero gap bits: no words at all
        let lists = vec![(0u32..200).collect::<Vec<_>>()];
        let pk = pack_lists(200, &lists);
        let (dofs, bw, bf, bm, bi, w) = pk.arenas();
        assert!(w.is_empty(), "consecutive runs need no gap words");
        assert_eq!(decode_all(&pk), lists);
        let rebuild = |bi: Vec<u32>, total: usize| {
            PackedPostings::from_parts(
                1,
                200,
                total,
                dofs.to_vec(),
                bw.to_vec(),
                bf.to_vec(),
                bm.to_vec(),
                bi,
                w.to_vec(),
            )
        };
        assert!(rebuild(bi.to_vec(), pk.total()).is_ok());
        // count lying HIGH: the zero-width run decodes past the skip
        // entry (and the id bound) — rejected, not emitted
        let mut high = bi.to_vec();
        high[1] = (high[1] & !0xFFFF) | 100; // block 1 really holds 72
        assert!(rebuild(high, pk.total()).is_err());
        // count lying LOW: the run stops short of the skip entry
        let mut low = bi.to_vec();
        low[1] = (low[1] & !0xFFFF) | 10;
        assert!(rebuild(low, pk.total()).is_err());
    }

    #[test]
    fn overflowing_deltas_rejected_not_panicking() {
        // a block whose gap pushes the id cursor past u32::MAX: decode
        // wraps (by design — no arithmetic panic even in debug) and the
        // strictly-increasing verification rejects the arena
        let r = PackedPostings::from_parts(
            1,
            usize::MAX, // id bound out of the way: the order check fires
            2,
            vec![0, 1],                  // one dim owning one block
            vec![0],                     // words start
            vec![u32::MAX - 1],          // first id near the top
            vec![u32::MAX - 1],          // skip entry (decode wraps here)
            vec![2u32 | (32 << 16)],     // count 2, width 32
            vec![u32::MAX],              // gap u32::MAX → wraps the cursor
        );
        let err = r.err().expect("wrapped delta must fail validation");
        assert!(
            err.to_string().contains("strictly"),
            "want the ordering check, got: {err}"
        );
    }

    #[test]
    fn from_parts_roundtrip_and_validation() {
        let lists = vec![vec![1u32, 4, 9, 200], vec![], vec![0, 1, 2]];
        let pk = pack_lists(300, &lists);
        let (dofs, bw, bf, bm, bi, w) = pk.arenas();
        let rebuild = |items: usize,
                       total: usize,
                       bm: Vec<u32>,
                       bi: Vec<u32>| {
            PackedPostings::from_parts(
                3,
                items,
                total,
                dofs.to_vec(),
                bw.to_vec(),
                bf.to_vec(),
                bm,
                bi,
                w.to_vec(),
            )
        };
        let back =
            rebuild(300, pk.total(), bm.to_vec(), bi.to_vec()).unwrap();
        assert_eq!(decode_all(&back), lists);

        // id beyond the claimed bound
        assert!(rebuild(100, pk.total(), bm.to_vec(), bi.to_vec()).is_err());
        // total disagrees with the blocks
        assert!(rebuild(300, 99, bm.to_vec(), bi.to_vec()).is_err());
        // lying skip entry
        let mut bad_max = bm.to_vec();
        bad_max[0] += 1;
        assert!(rebuild(300, pk.total(), bad_max, bi.to_vec()).is_err());
        // zero-count block
        let mut bad_info = bi.to_vec();
        bad_info[0] &= !0xFFFF;
        assert!(rebuild(300, pk.total(), bm.to_vec(), bad_info).is_err());
        // width > 32
        let mut bad_info = bi.to_vec();
        bad_info[0] |= 33 << 16;
        assert!(rebuild(300, pk.total(), bm.to_vec(), bad_info).is_err());
        // ragged dim offsets
        assert!(PackedPostings::from_parts(
            2,
            300,
            pk.total(),
            dofs.to_vec(),
            bw.to_vec(),
            bf.to_vec(),
            bm.to_vec(),
            bi.to_vec(),
            w.to_vec(),
        )
        .is_err());
    }
}
