//! Symmetric per-item int8 scalar quantization of the factor catalogue.
//!
//! Each item row `v` stores `codes[j] = round(v[j] / s)` clamped to
//! `[-127, 127]` with `s = max_j |v[j]| / 127` — symmetric quantization,
//! so no zero-point arithmetic pollutes the dot kernel. A query is
//! quantized once the same way, and the approximate score is
//!
//! ```text
//! ⟨u, v⟩ ≈ (Σ_j qu[j] · qv[j]) · s_u · s_v        (i8×i8 → i32 exact)
//! ```
//!
//! The integer accumulation is exact (k · 127² ≪ 2³¹ for any realistic
//! k), so the only error is the rounding of each coordinate — at most
//! `s/2` per coordinate, giving the bound derived in `docs/QUANT.md`.
//! The engine re-ranks the top `refine · κ` survivors with full f32
//! inner products against the original factors, which removes the
//! query-side quantization error entirely and bounds the end-to-end
//! accuracy loss by the item-side error alone.
//!
//! The store is id-addressed exactly like a
//! [`CandidateSource`](crate::engine::CandidateSource): row `id` holds
//! the codes of item `id`, dead ids hold a zeroed row (scale 0) and are
//! never scored because sources only return live candidates.

use crate::error::{GeomapError, Result};

/// Int8 codes + per-item scales for a factor catalogue (see module docs).
#[derive(Clone)]
pub struct QuantizedFactorStore {
    k: usize,
    /// Row-major codes: item `id` lives at `[id·k, (id+1)·k)`.
    codes: Vec<i8>,
    /// Per-item dequantization scale (`max|v| / 127`; 0 for dead rows).
    scales: Vec<f32>,
}

/// Quantize one factor into `codes` (len k), returning its scale.
///
/// Symmetric: `codes[j] · scale` reconstructs `v[j]` to within
/// `scale / 2`. An all-zero factor yields scale 0 and zero codes, and
/// so does any factor with a non-finite lane: `f32::max` would silently
/// discard a NaN operand, so the fold below promotes *any* NaN/±Inf
/// lane to an infinite max and the guard zeroes the row — a non-finite
/// factor can never produce a live-looking quantized row. (Ingestion
/// rejects such factors outright; this is defence in depth.)
pub fn quantize_into(factor: &[f32], codes: &mut [i8]) -> f32 {
    debug_assert_eq!(factor.len(), codes.len());
    let max = factor.iter().fold(0.0f32, |m, &x| {
        let a = x.abs();
        if a.is_finite() {
            m.max(a)
        } else {
            f32::INFINITY
        }
    });
    if max == 0.0 || !max.is_finite() {
        codes.fill(0);
        return 0.0;
    }
    let scale = max / 127.0;
    let inv = 127.0 / max;
    for (c, &x) in codes.iter_mut().zip(factor) {
        // max scaling keeps x·inv within ±127, so the cast cannot
        // saturate; round-half-away matches the error bound
        *c = (x * inv).round() as i8;
    }
    scale
}

/// Fixed-point inner product: i8×i8 products accumulated exactly in i32.
///
/// Four parallel accumulators, mirroring `linalg::ops::dot`, so LLVM
/// auto-vectorises the widening multiply-add without unsafe intrinsics.
/// This is the *scalar reference* arm of the dispatched kernel
/// ([`crate::kernels::Kernels::dot_i8`]); the scan hot path goes
/// through [`QuantizedFactorStore::score_with`], which may select an
/// explicit AVX2/NEON arm with bit-identical results.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] as i32 * b[j] as i32;
        s1 += a[j + 1] as i32 * b[j + 1] as i32;
        s2 += a[j + 2] as i32 * b[j + 2] as i32;
        s3 += a[j + 3] as i32 * b[j + 3] as i32;
    }
    let mut tail = 0i32;
    for j in chunks * 4..a.len() {
        tail += a[j] as i32 * b[j] as i32;
    }
    (s0 + s1) + (s2 + s3) + tail
}

impl QuantizedFactorStore {
    /// Empty store for dimensionality `k`.
    pub fn new(k: usize) -> Self {
        QuantizedFactorStore { k, codes: Vec::new(), scales: Vec::new() }
    }

    /// Quantize the id space `0..len` of a factor lookup. Ids where
    /// `factor_of` is `None` (dead / unmerged holes) get a zeroed row.
    pub fn from_factors<'a, F>(len: usize, k: usize, factor_of: F) -> Self
    where
        F: Fn(u32) -> Option<&'a [f32]>,
    {
        let mut store = QuantizedFactorStore::new(k);
        store.ensure_len(len);
        for id in 0..len as u32 {
            if let Some(f) = factor_of(id) {
                store.set_row(id, f);
            }
        }
        store
    }

    /// Grow to cover `len` ids (new rows zeroed; no-op when big enough).
    pub fn ensure_len(&mut self, len: usize) {
        if self.scales.len() < len {
            self.scales.resize(len, 0.0);
            self.codes.resize(len * self.k, 0);
        }
    }

    /// Requantize the row of `id` from its f32 factor.
    pub fn set_row(&mut self, id: u32, factor: &[f32]) {
        debug_assert_eq!(factor.len(), self.k);
        self.ensure_len(id as usize + 1);
        let lo = id as usize * self.k;
        self.scales[id as usize] =
            quantize_into(factor, &mut self.codes[lo..lo + self.k]);
    }

    /// Zero the row of `id` (removed item). Out-of-range ids are a no-op
    /// (the id never had a row to clear).
    pub fn clear_row(&mut self, id: u32) {
        if (id as usize) < self.scales.len() {
            let lo = id as usize * self.k;
            self.codes[lo..lo + self.k].fill(0);
            self.scales[id as usize] = 0.0;
        }
    }

    /// Approximate score of item `id` against a quantized query
    /// (`qcodes`, `qscale` from [`quantize_into`]).
    ///
    /// # Panics
    ///
    /// `id` must be covered (`id < self.len()`). Unlike
    /// [`clear_row`](Self::clear_row)'s tolerant out-of-range contract,
    /// this is a hot-path accessor and an uncovered id is a caller bug:
    /// debug builds fail the assert below, release builds panic on the
    /// slice range. The engine upholds the precondition by growing the
    /// store (`ensure_len` + `set_row`) in the same mutation that makes
    /// a new id visible to candidate generation, before any rescore can
    /// observe it.
    #[inline]
    pub fn score(&self, id: u32, qcodes: &[i8], qscale: f32) -> f32 {
        self.score_with(crate::kernels::active(), id, qcodes, qscale)
    }

    /// [`score`](Self::score) with a caller-resolved kernel table
    /// ([`crate::kernels::active`]), so batch rescore loops resolve the
    /// dispatch once per pass instead of once per candidate. Same
    /// precondition: `id` must be covered.
    #[inline]
    pub fn score_with(
        &self,
        kern: &crate::kernels::Kernels,
        id: u32,
        qcodes: &[i8],
        qscale: f32,
    ) -> f32 {
        debug_assert!(
            (id as usize) < self.scales.len(),
            "score id {id} is uncovered (store len {})",
            self.scales.len()
        );
        let lo = id as usize * self.k;
        let row = &self.codes[lo..lo + self.k];
        (kern.dot_i8)(qcodes, row) as f32 * self.scales[id as usize] * qscale
    }

    /// Covered id space.
    pub fn len(&self) -> usize {
        self.scales.len()
    }

    /// True when no id is covered.
    pub fn is_empty(&self) -> bool {
        self.scales.is_empty()
    }

    /// Factor dimensionality k.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Resident bytes: 1 byte per code + 4 per scale.
    pub fn memory_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }

    /// The raw code arena (row-major), for the snapshot codec.
    pub fn codes(&self) -> &[i8] {
        &self.codes
    }

    /// The per-item scales, for the snapshot codec.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Reassemble from snapshot arenas, validating shape agreement.
    pub fn from_parts(
        k: usize,
        codes: Vec<i8>,
        scales: Vec<f32>,
    ) -> Result<QuantizedFactorStore> {
        if codes.len() != scales.len() * k {
            return Err(GeomapError::Artifact(format!(
                "quant store: {} codes disagree with {} items of dim {k}",
                codes.len(),
                scales.len()
            )));
        }
        if scales.iter().any(|s| !s.is_finite() || *s < 0.0) {
            return Err(GeomapError::Artifact(
                "quant store: scales must be finite and non-negative".into(),
            ));
        }
        Ok(QuantizedFactorStore { k, codes, scales })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::dot;
    use crate::rng::Rng;

    fn gaussian(k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seeded(seed);
        (0..k).map(|_| rng.gaussian_f32()).collect()
    }

    #[test]
    fn dot_i8_matches_naive_all_lengths() {
        let mut rng = Rng::seeded(1);
        for len in 0..40 {
            let a: Vec<i8> =
                (0..len).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> =
                (0..len).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let want: i32 =
                a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
            assert_eq!(dot_i8(&a, &b), want, "len={len}");
        }
    }

    #[test]
    fn quantize_bounds_per_coordinate_error() {
        for seed in 0..20u64 {
            let v = gaussian(32, seed);
            let mut codes = vec![0i8; 32];
            let s = quantize_into(&v, &mut codes);
            assert!(s > 0.0);
            for (c, &x) in codes.iter().zip(&v) {
                let err = (*c as f32 * s - x).abs();
                assert!(
                    err <= s * 0.5 + 1e-6,
                    "coordinate error {err} exceeds s/2 = {}",
                    s * 0.5
                );
            }
        }
    }

    #[test]
    fn zero_factor_quantizes_to_zero_scale() {
        let mut codes = vec![7i8; 8];
        let s = quantize_into(&[0.0; 8], &mut codes);
        assert_eq!(s, 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn non_finite_factor_quantizes_to_dead_row() {
        // an f32::max fold discards NaN, so a NaN lane must not slip a
        // live-looking scale through — every non-finite lane (in any
        // position, including past larger finite lanes) zeroes the row
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for pos in 0..4 {
                let mut v = [3.0f32, -1.0, 0.5, 2.0];
                v[pos] = bad;
                let mut codes = vec![7i8; 4];
                let s = quantize_into(&v, &mut codes);
                assert_eq!(s, 0.0, "bad={bad} pos={pos}");
                assert!(codes.iter().all(|&c| c == 0));
            }
        }
    }

    #[test]
    #[should_panic]
    fn score_uncovered_id_panics() {
        // the documented precondition: debug builds hit the assert,
        // release builds the slice range — never a silent wrong answer
        let store = QuantizedFactorStore::new(4);
        let _ = store.score(0, &[1, 2, 3, 4], 1.0);
    }

    #[test]
    fn approximate_scores_track_exact_dots() {
        let k = 32;
        let mut store = QuantizedFactorStore::new(k);
        let rows: Vec<Vec<f32>> =
            (0..50).map(|i| gaussian(k, 100 + i)).collect();
        for (id, r) in rows.iter().enumerate() {
            store.set_row(id as u32, r);
        }
        let u = gaussian(k, 999);
        let mut qcodes = vec![0i8; k];
        let qscale = quantize_into(&u, &mut qcodes);
        // relative error bound: |Δ| ≤ (s_u/2)·Σ|qv·s_v| + (s_v/2)·Σ|qu·s_u|
        // ≈ (s_u + s_v)/2 · √k · ‖·‖; empirically a few percent of ‖u‖‖v‖
        for (id, r) in rows.iter().enumerate() {
            let approx = store.score(id as u32, &qcodes, qscale);
            let exact = dot(&u, r);
            let norm: f32 = dot(&u, &u).sqrt() * dot(r, r).sqrt();
            assert!(
                (approx - exact).abs() <= 0.05 * norm + 1e-4,
                "id {id}: approx {approx} vs exact {exact} (norms {norm})"
            );
        }
    }

    #[test]
    fn ranking_survives_quantization() {
        // the top item by a clear margin stays the top item quantized
        let k = 16;
        let mut store = QuantizedFactorStore::new(k);
        let u = gaussian(k, 5);
        store.set_row(0, &u); // perfectly aligned → dominant score
        for id in 1..20u32 {
            let mut v = gaussian(k, 200 + id as u64);
            for x in &mut v {
                *x *= 0.3;
            }
            store.set_row(id, &v);
        }
        let mut qcodes = vec![0i8; k];
        let qscale = quantize_into(&u, &mut qcodes);
        let best = (0..20u32)
            .max_by(|&a, &b| {
                store
                    .score(a, &qcodes, qscale)
                    .partial_cmp(&store.score(b, &qcodes, qscale))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(best, 0);
    }

    #[test]
    fn mutation_updates_rows() {
        let k = 8;
        let mut store = QuantizedFactorStore::new(k);
        store.ensure_len(4);
        assert_eq!(store.len(), 4);
        let f = gaussian(k, 3);
        store.set_row(2, &f);
        let mut q = vec![0i8; k];
        let qs = quantize_into(&f, &mut q);
        assert!(store.score(2, &q, qs) > 0.0);
        store.clear_row(2);
        assert_eq!(store.score(2, &q, qs), 0.0);
        // appending past the current length grows the store
        store.set_row(7, &f);
        assert_eq!(store.len(), 8);
        assert_eq!(store.memory_bytes(), 8 * k + 8 * 4);
        // clearing an id we never covered is a no-op
        store.clear_row(100);
        assert_eq!(store.len(), 8);
    }

    #[test]
    fn parts_roundtrip_and_validation() {
        let k = 8;
        let mut store = QuantizedFactorStore::new(k);
        for id in 0..5u32 {
            store.set_row(id, &gaussian(k, id as u64));
        }
        let back = QuantizedFactorStore::from_parts(
            k,
            store.codes().to_vec(),
            store.scales().to_vec(),
        )
        .unwrap();
        assert_eq!(back.len(), 5);
        assert_eq!(back.codes(), store.codes());
        assert_eq!(back.scales(), store.scales());
        // ragged arenas rejected
        assert!(QuantizedFactorStore::from_parts(
            k,
            vec![0i8; 7],
            vec![1.0]
        )
        .is_err());
        // non-finite scales rejected
        assert!(QuantizedFactorStore::from_parts(
            1,
            vec![0i8; 2],
            vec![1.0, f32::NAN]
        )
        .is_err());
    }
}
