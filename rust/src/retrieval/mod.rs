//! Retrieval pipeline: inverted-index pruning + exact rescoring (paper §6).
//!
//! [`Retriever`] owns the mapped item index and the dense item factors;
//! `top_k` prunes with the index then rescores the survivors exactly.
//! [`RecoveryReport`] implements the paper's two evaluation metrics:
//! per-user **% items discarded** and **recovery accuracy** (fraction of
//! the true top-κ that survives pruning).
//!
//! New code should prefer the backend-agnostic [`crate::engine::Engine`]
//! facade (the `Retriever` also implements
//! [`crate::engine::CandidateSource`], and the geomap engine adds
//! incremental catalogue mutation); this immutable retriever remains the
//! minimal single-backend reference implementation.

mod topk;

pub use topk::TopK;

use crate::embedding::Mapper;
use crate::error::Result;
use crate::index::{InvertedIndex, QueryScratch};
use crate::linalg::ops::dot;
use crate::linalg::Matrix;

/// A scored retrieval result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    /// Item id.
    pub id: u32,
    /// Exact inner-product score.
    pub score: f32,
}

/// Index-pruned retriever with exact rescoring.
pub struct Retriever {
    mapper: Mapper,
    index: InvertedIndex,
    items: Matrix,
    /// Minimum support overlap for a candidate (paper uses 1).
    pub min_overlap: usize,
}

impl Retriever {
    /// Map `items` with `mapper`, build the index, and take ownership.
    pub fn build(mapper: Mapper, items: Matrix) -> Result<Self> {
        let index = InvertedIndex::build(&mapper, &items)?;
        Ok(Retriever { mapper, index, items, min_overlap: 1 })
    }

    /// Number of items served.
    pub fn items(&self) -> usize {
        self.items.rows()
    }

    /// The underlying inverted index.
    pub fn index(&self) -> &InvertedIndex {
        &self.index
    }

    /// The mapper (schema) in use.
    pub fn mapper(&self) -> &Mapper {
        &self.mapper
    }

    /// Dense item factors.
    pub fn item_factors(&self) -> &Matrix {
        &self.items
    }

    /// Candidate ids for a user factor (pruning only, no scores).
    pub fn candidates(&self, user: &[f32]) -> Result<Vec<u32>> {
        let phi = self.mapper.map(user)?;
        Ok(self.index.query(&phi, self.min_overlap))
    }

    /// Allocation-lean candidate retrieval into caller buffers.
    pub fn candidates_into(
        &self,
        user: &[f32],
        scratch: &mut QueryScratch,
        out: &mut Vec<u32>,
    ) -> Result<()> {
        let phi = self.mapper.map(user)?;
        self.index.query_into(&phi, self.min_overlap, scratch, out);
        Ok(())
    }

    /// Hot-path variant of [`candidates_into`]: unique ids, unsorted
    /// (posting-traversal order). Used by the batch worker, which unions
    /// and sorts across the whole batch anyway.
    pub fn candidates_into_unordered(
        &self,
        user: &[f32],
        scratch: &mut QueryScratch,
        out: &mut Vec<u32>,
    ) -> Result<()> {
        let phi = self.mapper.map(user)?;
        self.index.query_into_unordered(&phi, self.min_overlap, scratch, out);
        Ok(())
    }

    /// Top-κ via prune + exact rescore.
    pub fn top_k(&self, user: &[f32], kappa: usize) -> Result<Vec<Scored>> {
        let cands = self.candidates(user)?;
        let mut heap = TopK::new(kappa);
        for &id in &cands {
            let s = dot(user, self.items.row(id as usize));
            heap.push(id, s);
        }
        Ok(heap.into_sorted())
    }

    /// Brute-force top-κ over every item (the baseline the paper speeds up).
    pub fn top_k_brute(&self, user: &[f32], kappa: usize) -> Vec<Scored> {
        brute_force_top_k(user, &self.items, kappa)
    }
}

/// Exact top-κ by scanning all items.
pub fn brute_force_top_k(user: &[f32], items: &Matrix, kappa: usize) -> Vec<Scored> {
    let mut heap = TopK::new(kappa);
    for id in 0..items.rows() {
        heap.push(id as u32, dot(user, items.row(id)));
    }
    heap.into_sorted()
}

/// Per-user evaluation record.
#[derive(Clone, Copy, Debug)]
pub struct UserEval {
    /// Fraction of the catalogue discarded by pruning, in [0, 1].
    pub discarded: f64,
    /// |retrieved ∩ true top-κ| / κ.
    pub accuracy: f64,
}

/// Aggregated evaluation over a user set (paper figures 2-5).
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Per-user records, in user order.
    pub per_user: Vec<UserEval>,
}

impl RecoveryReport {
    /// Evaluate a candidate-set producer against ground-truth top-κ.
    ///
    /// `candidates(u)` returns the surviving item ids for user row `u`;
    /// ground truth is the exact top-κ under dense inner product — the
    /// paper's "relevant items" for both synthetic (true rating matrix
    /// R = UVᵀ) and MovieLens (learned-factor scores).
    pub fn evaluate(
        users: &Matrix,
        items: &Matrix,
        kappa: usize,
        mut candidates: impl FnMut(usize, &[f32]) -> Vec<u32>,
    ) -> Self {
        let n_items = items.rows();
        let mut per_user = Vec::with_capacity(users.rows());
        for u in 0..users.rows() {
            let uf = users.row(u);
            let truth = brute_force_top_k(uf, items, kappa);
            let cands = candidates(u, uf);
            let mut cand_set = vec![false; n_items];
            for &c in &cands {
                cand_set[c as usize] = true;
            }
            let hit = truth.iter().filter(|s| cand_set[s.id as usize]).count();
            per_user.push(UserEval {
                discarded: 1.0 - cands.len() as f64 / n_items as f64,
                accuracy: hit as f64 / truth.len().max(1) as f64,
            });
        }
        RecoveryReport { per_user }
    }

    /// Mean fraction discarded.
    pub fn mean_discarded(&self) -> f64 {
        mean(self.per_user.iter().map(|e| e.discarded))
    }

    /// Std-dev of fraction discarded (fig 4 error bars).
    pub fn std_discarded(&self) -> f64 {
        std(self.per_user.iter().map(|e| e.discarded))
    }

    /// Mean recovery accuracy.
    pub fn mean_accuracy(&self) -> f64 {
        mean(self.per_user.iter().map(|e| e.accuracy))
    }

    /// Histogram of % discarded over users with `bins` equal bins on
    /// [0, 100] — the paper's figures 2a/3a.
    pub fn discard_histogram(&self, bins: usize) -> Vec<usize> {
        let mut h = vec![0usize; bins];
        for e in &self.per_user {
            let pct = (e.discarded * 100.0).clamp(0.0, 100.0);
            let b = ((pct / 100.0) * bins as f64) as usize;
            h[b.min(bins - 1)] += 1;
        }
        h
    }

    /// Speed-up implied by the mean discard rate: 1 / (1 - η) (paper §6).
    pub fn implied_speedup(&self) -> f64 {
        let eta = self.mean_discarded();
        if eta >= 1.0 {
            f64::INFINITY
        } else {
            1.0 / (1.0 - eta)
        }
    }
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut s, mut n) = (0.0, 0usize);
    for x in xs {
        s += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

fn std(xs: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = xs.collect();
    if v.len() < 2 {
        return 0.0;
    }
    let m = v.iter().sum::<f64>() / v.len() as f64;
    (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{PermutationKind, TessellationKind};
    use crate::rng::Rng;
    use crate::testing::prop;

    fn retriever(k: usize, n: usize, seed: u64) -> Retriever {
        let mapper =
            Mapper::new(TessellationKind::Ternary, PermutationKind::ParseTree, k);
        let mut rng = Rng::seeded(seed);
        let items = Matrix::gaussian(&mut rng, n, k, 1.0);
        Retriever::build(mapper, items).unwrap()
    }

    #[test]
    fn top_k_scores_are_exact_and_sorted() {
        let r = retriever(8, 200, 11);
        let mut rng = Rng::seeded(5);
        let user: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        let got = r.top_k(&user, 10).unwrap();
        assert!(got.len() <= 10);
        for w in got.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        for s in &got {
            let exact = dot(&user, r.item_factors().row(s.id as usize));
            assert!((s.score - exact).abs() < 1e-5);
        }
    }

    #[test]
    fn retrieved_topk_is_topk_of_candidates() {
        prop(30, |g| {
            let k = g.usize_in(2..=10);
            let n = g.usize_in(10..=100);
            let r = retriever(k, n, g.case_seed);
            let user = g.unit_vector(k);
            let kappa = g.usize_in(1..=10);
            let cands = r.candidates(&user).unwrap();
            let got = r.top_k(&user, kappa).unwrap();
            // recompute expected: sort candidate scores desc
            let mut exp: Vec<Scored> = cands
                .iter()
                .map(|&id| Scored {
                    id,
                    score: dot(&user, r.item_factors().row(id as usize)),
                })
                .collect();
            exp.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
            exp.truncate(kappa);
            assert_eq!(got.len(), exp.len());
            for (g1, e1) in got.iter().zip(&exp) {
                assert!((g1.score - e1.score).abs() < 1e-6);
            }
        });
    }

    #[test]
    fn brute_force_is_ground_truth() {
        let r = retriever(6, 50, 3);
        let mut rng = Rng::seeded(9);
        let user: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
        let brute = r.top_k_brute(&user, 5);
        assert_eq!(brute.len(), 5);
        // the true max must be brute[0]
        let max = (0..50)
            .map(|i| dot(&user, r.item_factors().row(i)))
            .fold(f32::NEG_INFINITY, f32::max);
        assert!((brute[0].score - max).abs() < 1e-6);
    }

    #[test]
    fn report_metrics_bounds() {
        let k = 8;
        let r = retriever(k, 300, 21);
        let mut rng = Rng::seeded(17);
        let users = Matrix::gaussian(&mut rng, 40, k, 1.0);
        let rep = RecoveryReport::evaluate(&users, r.item_factors(), 10, |_, u| {
            r.candidates(u).unwrap()
        });
        assert_eq!(rep.per_user.len(), 40);
        for e in &rep.per_user {
            assert!((0.0..=1.0).contains(&e.discarded));
            assert!((0.0..=1.0).contains(&e.accuracy));
        }
        assert!(rep.mean_discarded() > 0.0, "should discard something");
        assert!(rep.mean_accuracy() > 0.3, "should recover a fair share");
        assert!(rep.implied_speedup() >= 1.0);
        let h = rep.discard_histogram(10);
        assert_eq!(h.iter().sum::<usize>(), 40);
    }

    #[test]
    fn all_candidates_means_perfect_accuracy() {
        let k = 4;
        let r = retriever(k, 60, 31);
        let mut rng = Rng::seeded(1);
        let users = Matrix::gaussian(&mut rng, 10, k, 1.0);
        let rep = RecoveryReport::evaluate(&users, r.item_factors(), 5, |_, _| {
            (0..60u32).collect()
        });
        assert!((rep.mean_accuracy() - 1.0).abs() < 1e-12);
        assert!(rep.mean_discarded().abs() < 1e-12);
        assert!((rep.implied_speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_candidates_zero_accuracy() {
        let k = 4;
        let r = retriever(k, 60, 37);
        let mut rng = Rng::seeded(2);
        let users = Matrix::gaussian(&mut rng, 5, k, 1.0);
        let rep =
            RecoveryReport::evaluate(&users, r.item_factors(), 5, |_, _| vec![]);
        assert_eq!(rep.mean_accuracy(), 0.0);
        assert_eq!(rep.mean_discarded(), 1.0);
    }
}
