//! Bounded top-κ accumulator (min-heap of size κ).

use super::Scored;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Wrapper giving `Scored` a *reverse* (min-heap) ordering by score, with
/// id as a deterministic tie-break.
#[derive(Clone, Copy, Debug, PartialEq)]
struct MinScored(Scored);

impl Eq for MinScored {}

impl Ord for MinScored {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed: smaller score = "greater" for the max-heap ⇒ min-heap
        other
            .0
            .score
            .partial_cmp(&self.0.score)
            .unwrap_or(Ordering::Equal)
            .then(other.0.id.cmp(&self.0.id))
    }
}

impl PartialOrd for MinScored {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Keeps the κ highest-scoring entries seen so far.
pub struct TopK {
    kappa: usize,
    heap: BinaryHeap<MinScored>,
}

impl TopK {
    /// Accumulator for the top `kappa` entries (kappa ≥ 1 recommended;
    /// kappa = 0 yields an always-empty result).
    pub fn new(kappa: usize) -> Self {
        TopK { kappa, heap: BinaryHeap::with_capacity(kappa + 1) }
    }

    /// Offer one scored item.
    ///
    /// Admission ties break by ascending id (an equal-scoring entry
    /// evicts the largest tied id), matching the `into_sorted` tie rule.
    /// The kept set is therefore a pure function of the offered
    /// `(id, score)` multiset — push order, and hence shard [`merge`]
    /// order, never changes the result.
    ///
    /// [`merge`]: TopK::merge
    #[inline]
    pub fn push(&mut self, id: u32, score: f32) {
        if self.kappa == 0 {
            return;
        }
        if self.heap.len() < self.kappa {
            self.heap.push(MinScored(Scored { id, score }));
        } else if let Some(min) = self.heap.peek() {
            // peek() is the smallest score, largest id among its ties
            if score > min.0.score
                || (score == min.0.score && id < min.0.id)
            {
                self.heap.pop();
                self.heap.push(MinScored(Scored { id, score }));
            }
        }
    }

    /// Current number of kept entries.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing has been kept.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Smallest kept score (threshold for admission once full).
    pub fn threshold(&self) -> Option<f32> {
        self.heap.peek().map(|m| m.0.score)
    }

    /// Extract the kept entries in arbitrary order — for consumers that
    /// re-rank anyway (e.g. the quantized refinement pass), skipping
    /// [`into_sorted`](TopK::into_sorted)'s O(κ log κ) sort.
    pub fn into_unsorted(self) -> Vec<Scored> {
        self.heap.into_iter().map(|m| m.0).collect()
    }

    /// Extract results sorted by descending score (ties: ascending id).
    pub fn into_sorted(self) -> Vec<Scored> {
        let mut v: Vec<Scored> = self.heap.into_iter().map(|m| m.0).collect();
        v.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        v
    }

    /// Merge another accumulator into this one (shard fan-in).
    ///
    /// Assumes the two accumulators cover *disjoint* id spaces, which
    /// shard fan-in guarantees (each shard owns a contiguous global id
    /// range). An id present in both sides is treated as two distinct
    /// entries — no deduplication — so both copies can survive into the
    /// merged top-κ. Tie scores stay deterministic: equal scores order
    /// by ascending id, both during eviction and in `into_sorted`.
    pub fn merge(&mut self, other: TopK) {
        for m in other.heap {
            self.push(m.0.id, m.0.score);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;

    #[test]
    fn keeps_top_k() {
        let mut t = TopK::new(3);
        for (id, s) in [(0, 1.0), (1, 5.0), (2, 3.0), (3, 4.0), (4, 2.0)] {
            t.push(id, s);
        }
        let out = t.into_sorted();
        assert_eq!(
            out.iter().map(|s| s.id).collect::<Vec<_>>(),
            vec![1, 3, 2]
        );
    }

    #[test]
    fn fewer_items_than_kappa() {
        let mut t = TopK::new(10);
        t.push(7, 1.5);
        let out = t.into_sorted();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 7);
    }

    #[test]
    fn kappa_zero_is_empty() {
        let mut t = TopK::new(0);
        t.push(1, 10.0);
        assert!(t.is_empty());
        assert!(t.into_sorted().is_empty());
    }

    #[test]
    fn matches_full_sort_property() {
        prop(100, |g| {
            let n = g.usize_in(0..=200);
            let kappa = g.usize_in(1..=20);
            let scores: Vec<f32> = (0..n).map(|_| g.gaussian()).collect();
            let mut t = TopK::new(kappa);
            for (i, &s) in scores.iter().enumerate() {
                t.push(i as u32, s);
            }
            let got = t.into_sorted();
            let mut want: Vec<(usize, f32)> =
                scores.iter().copied().enumerate().collect();
            want.sort_by(|a, b| {
                b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
            });
            want.truncate(kappa);
            assert_eq!(got.len(), want.len());
            for (g1, w1) in got.iter().zip(&want) {
                assert_eq!(g1.id as usize, w1.0);
            }
        });
    }

    #[test]
    fn merge_equals_combined_stream() {
        prop(50, |g| {
            let kappa = g.usize_in(1..=8);
            let a: Vec<f32> = g.vec_gaussian(0..=50);
            let b: Vec<f32> = g.vec_gaussian(0..=50);
            let mut ta = TopK::new(kappa);
            for (i, &s) in a.iter().enumerate() {
                ta.push(i as u32, s);
            }
            let mut tb = TopK::new(kappa);
            for (i, &s) in b.iter().enumerate() {
                tb.push((1000 + i) as u32, s);
            }
            ta.merge(tb);
            let merged = ta.into_sorted();
            let mut tc = TopK::new(kappa);
            for (i, &s) in a.iter().enumerate() {
                tc.push(i as u32, s);
            }
            for (i, &s) in b.iter().enumerate() {
                tc.push((1000 + i) as u32, s);
            }
            let direct = tc.into_sorted();
            assert_eq!(merged, direct);
        });
    }

    #[test]
    fn merge_with_duplicate_ids_keeps_both_copies() {
        // merge assumes disjoint shard id spaces; feeding the same id
        // from both sides documents the contract: no deduplication
        let mut a = TopK::new(4);
        a.push(7, 3.0);
        a.push(1, 1.0);
        let mut b = TopK::new(4);
        b.push(7, 2.0); // same id, different score
        b.push(2, 0.5);
        a.merge(b);
        let out = a.into_sorted();
        let sevens: Vec<f32> = out
            .iter()
            .filter(|s| s.id == 7)
            .map(|s| s.score)
            .collect();
        assert_eq!(sevens, vec![3.0, 2.0], "both copies of id 7 survive");
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn merge_ties_break_deterministically_by_id() {
        // all-equal scores: the κ smallest ids must win, in order —
        // regardless of which side of the merge they came from
        let mut a = TopK::new(3);
        for id in [9u32, 4, 6] {
            a.push(id, 1.0);
        }
        let mut b = TopK::new(3);
        for id in [2u32, 8, 5] {
            b.push(id, 1.0);
        }
        a.merge(b);
        let ids: Vec<u32> = a.into_sorted().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![2, 4, 5], "ties evict the largest id first");
        // and the mirror-order merge agrees exactly
        let mut a2 = TopK::new(3);
        for id in [2u32, 8, 5] {
            a2.push(id, 1.0);
        }
        let mut b2 = TopK::new(3);
        for id in [9u32, 4, 6] {
            b2.push(id, 1.0);
        }
        a2.merge(b2);
        let ids2: Vec<u32> = a2.into_sorted().iter().map(|s| s.id).collect();
        assert_eq!(ids, ids2, "merge order must not change tie results");
    }

    #[test]
    fn threshold_tracks_min() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), None);
        t.push(0, 5.0);
        t.push(1, 3.0);
        assert_eq!(t.threshold(), Some(3.0));
        t.push(2, 4.0);
        assert_eq!(t.threshold(), Some(4.0));
    }
}
