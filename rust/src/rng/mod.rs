//! Deterministic pseudo-random substrate (no external `rand` crate).
//!
//! * [`Rng`] — xoshiro256++ core seeded via SplitMix64, with uniform,
//!   Gaussian (Muller 1959 — the same construction the paper cites via
//!   [20] for hypersphere point picking), Zipf and shuffling helpers.
//!
//! Everything here is reproducible from a single `u64` seed so that every
//! experiment in EXPERIMENTS.md can be regenerated bit-for-bit.

mod zipf;

pub use zipf::Zipf;

/// SplitMix64 step — used to expand a single seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator with distribution helpers.
///
/// Not cryptographic; chosen for speed, quality (passes BigCrush) and a
/// tiny, dependency-free implementation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire rejection-free-ish; n > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply keeps bias < 2^-64 — fine for experiments.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fill a slice with i.i.d. N(0, 1) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n, "cannot sample {m} distinct from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..m {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(m);
        idx
    }

    /// Fork a new independent generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seeded(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seeded(17);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seeded(23);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::seeded(29);
        let mut a = base.fork();
        let mut b = base.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
