//! Zipf-distributed sampler — used by the MovieLens-like synthetic ratings
//! generator to reproduce the heavy-tailed item popularity of real
//! recommendation logs (docs/ARCHITECTURE.md §Offline substitutions).

use super::Rng;

/// Zipf(n, s) sampler over ranks {0, 1, …, n-1} with exponent `s`.
///
/// Uses a precomputed CDF + binary search: O(n) setup, O(log n) per draw.
/// n in our workloads is ≤ a few thousand items, so the table is tiny.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for `n` ranks with exponent `s` (s ≥ 0; s = 0 is
    /// uniform, s ≈ 1 matches classic popularity curves).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf over empty support");
        assert!(s >= 0.0, "zipf exponent must be non-negative");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for rank in 1..=n {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        // guard against fp round-off at the tail
        *cdf.last_mut().unwrap() = 1.0;
        Zipf { cdf }
    }

    /// Draw one rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // first index with cdf >= u
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).expect("cdf is finite"))
        {
            Ok(i) => i,
            Err(i) => i,
        }
    }

    /// Support size.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the support is empty (never — kept for API completeness).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(100, 1.0);
        let mut rng = Rng::seeded(5);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 100);
        }
    }

    #[test]
    fn rank_zero_is_most_popular() {
        let z = Zipf::new(50, 1.2);
        let mut rng = Rng::seeded(6);
        let mut counts = vec![0usize; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        let max = counts.iter().copied().max().unwrap();
        assert_eq!(counts[0], max, "head rank must dominate");
        // monotone-ish decay: head ≫ tail
        assert!(counts[0] > 5 * counts[40]);
    }

    #[test]
    fn zero_exponent_is_uniform() {
        let z = Zipf::new(10, 0.0);
        let mut rng = Rng::seeded(9);
        let mut counts = vec![0usize; 10];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / 100_000.0;
            assert!((frac - 0.1).abs() < 0.01, "frac={frac}");
        }
    }
}
