//! Golden-case verification: run every artifact that ships golden
//! input/output JSON (emitted by `aot.py`) through the PJRT runtime and
//! compare against the jax-computed outputs.
//!
//! This is the end-to-end proof that the L2/L1 python build path and the
//! L3 rust execution path agree on numerics.

use super::XlaRuntime;
use crate::configx::Json;
use crate::error::{GeomapError, Result};

/// One golden case: concrete inputs and expected outputs (flat buffers).
pub struct GoldenCase {
    /// Flat row-major f32 inputs, in argument order.
    pub inputs: Vec<Vec<f32>>,
    /// Flat expected outputs (both f32 and i32 outputs are stored as f64
    /// in JSON; compare via [`verify_goldens`]).
    pub outputs: Vec<Vec<f64>>,
}

/// Parse a golden JSON file (a list of cases).
pub fn load_golden(path: &str) -> Result<Vec<GoldenCase>> {
    let j = Json::from_file(path)?;
    let mut cases = Vec::new();
    for c in j.as_arr()? {
        let inputs = c
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|a| a.as_f32_vec())
            .collect::<Result<Vec<_>>>()?;
        let outputs = c
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(|a| {
                a.as_arr()?
                    .iter()
                    .map(|v| v.as_f64())
                    .collect::<Result<Vec<f64>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        cases.push(GoldenCase { inputs, outputs });
    }
    Ok(cases)
}

/// Run every golden case in the runtime's manifest; returns the number of
/// cases checked. Errors carry the artifact name and mismatch position.
pub fn verify_goldens(runtime: &XlaRuntime) -> Result<usize> {
    let entries: Vec<(String, String)> = runtime
        .manifest
        .entries
        .iter()
        .filter_map(|e| {
            e.golden
                .as_ref()
                .map(|g| (e.name.clone(), format!("{}/{g}", runtime.manifest.dir)))
        })
        .collect();
    let mut checked = 0usize;
    for (name, golden_path) in entries {
        let module = runtime.module(&name)?;
        let cases = load_golden(&golden_path)?;
        for (ci, case) in cases.iter().enumerate() {
            let input_refs: Vec<&[f32]> =
                case.inputs.iter().map(Vec::as_slice).collect();
            let outs = module.run_f32(&input_refs)?;
            if outs.len() != case.outputs.len() {
                return Err(GeomapError::Artifact(format!(
                    "{name} case {ci}: {} outputs, golden has {}",
                    outs.len(),
                    case.outputs.len()
                )));
            }
            for (oi, (lit, want)) in outs.iter().zip(&case.outputs).enumerate() {
                let spec = &module.entry.outputs[oi];
                let got: Vec<f64> = match spec.dtype.as_str() {
                    "i32" => lit
                        .to_vec::<i32>()?
                        .into_iter()
                        .map(|v| v as f64)
                        .collect(),
                    _ => lit
                        .to_vec::<f32>()?
                        .into_iter()
                        .map(|v| v as f64)
                        .collect(),
                };
                if got.len() != want.len() {
                    return Err(GeomapError::Artifact(format!(
                        "{name} case {ci} out {oi}: len {} != {}",
                        got.len(),
                        want.len()
                    )));
                }
                for (pos, (g, w)) in got.iter().zip(want).enumerate() {
                    let tol = 1e-4 * w.abs().max(1.0);
                    if (g - w).abs() > tol {
                        return Err(GeomapError::Artifact(format!(
                            "{name} case {ci} out {oi} pos {pos}: {g} != {w}"
                        )));
                    }
                }
            }
            checked += 1;
        }
    }
    Ok(checked)
}
