//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `artifacts/manifest.json` lists every AOT-lowered module with its HLO
//! text file, static input/output shapes, and (for small modules) a golden
//! input/output JSON used by the integration tests.

use crate::configx::Json;
use crate::error::{GeomapError, Result};

/// What a module computes (mirrors `meta.kind` in aot.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// `(B,k) x (T,k) -> (B,T)` scores.
    Score,
    /// `(B,k) x (T,k) -> ((B,κ), (B,κ))` fused score + top-κ.
    ScoreTopk,
    /// `(B,k) x (T,k) x (T,) -> (B,T)` masked scores (-1e30 where mask=0).
    ScoreMasked,
    /// `(N,k) -> (N,k)` Algorithm 2 tessellation.
    TessTernary,
    /// `(N,k) -> (N,k)` Algorithm 3 D-ary tessellation.
    TessDary,
}

impl Kind {
    fn parse(s: &str) -> Result<Kind> {
        match s {
            "score" => Ok(Kind::Score),
            "score_topk" => Ok(Kind::ScoreTopk),
            "score_masked" => Ok(Kind::ScoreMasked),
            "tess_ternary" => Ok(Kind::TessTernary),
            "tess_dary" => Ok(Kind::TessDary),
            _ => Err(GeomapError::Artifact(format!("unknown kind '{s}'"))),
        }
    }
}

/// A tensor shape + dtype declaration.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimensions.
    pub shape: Vec<usize>,
    /// `f32` or `i32`.
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: j.get("shape")?.as_usize_vec()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }

    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT module in the manifest.
#[derive(Clone, Debug)]
pub struct Entry {
    /// Module name (artifact stem).
    pub name: String,
    /// HLO text filename relative to the artifact dir.
    pub file: String,
    /// Module kind.
    pub kind: Kind,
    /// Static meta dims: b/k/t/kappa/n/d as present for the kind.
    pub meta: MetaDims,
    /// Input tensor specs, in argument order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs, in tuple order.
    pub outputs: Vec<TensorSpec>,
    /// Relative path of the golden-cases JSON, if emitted.
    pub golden: Option<String>,
}

/// Static dimensions from `meta` (zero when absent for the kind).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetaDims {
    /// Query batch B.
    pub b: usize,
    /// Factor dim k.
    pub k: usize,
    /// Item tile T.
    pub t: usize,
    /// Top-κ width.
    pub kappa: usize,
    /// Row count N (tessellation modules).
    pub n: usize,
    /// Grid resolution D (D-ary tessellation).
    pub d: usize,
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: String,
    /// All modules.
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let j = Json::from_file(&path)?;
        Self::from_json(dir, &j)
    }

    /// Parse from an already-loaded JSON document.
    pub fn from_json(dir: &str, j: &Json) -> Result<Manifest> {
        let format = j.get("format")?.as_str()?;
        if format != "hlo-text-v1" {
            return Err(GeomapError::Artifact(format!(
                "unsupported manifest format '{format}'"
            )));
        }
        let mut entries = Vec::new();
        for e in j.get("entries")?.as_arr()? {
            let meta = e.get("meta")?;
            let dim = |key: &str| -> usize {
                meta.opt(key).and_then(|v| v.as_usize().ok()).unwrap_or(0)
            };
            entries.push(Entry {
                name: e.get("name")?.as_str()?.to_string(),
                file: e.get("file")?.as_str()?.to_string(),
                kind: Kind::parse(meta.get("kind")?.as_str()?)?,
                meta: MetaDims {
                    b: dim("b"),
                    k: dim("k"),
                    t: dim("t"),
                    kappa: dim("kappa"),
                    n: dim("n"),
                    d: dim("d"),
                },
                inputs: e
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: e
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                golden: e
                    .opt("golden")
                    .map(|g| g.as_str().map(str::to_string))
                    .transpose()?,
            });
        }
        Ok(Manifest { dir: dir.to_string(), entries })
    }

    /// Look up an entry by name.
    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries.iter().find(|e| e.name == name).ok_or_else(|| {
            GeomapError::Artifact(format!("no artifact named '{name}'"))
        })
    }

    /// Entries of a given kind.
    pub fn of_kind(&self, kind: Kind) -> impl Iterator<Item = &Entry> {
        self.entries.iter().filter(move |e| e.kind == kind)
    }

    /// The smallest `score` entry whose k matches and whose (B, T) fit
    /// the requested batch/tile (the runtime pads up to it).
    pub fn best_scorer(&self, k: usize, b: usize, t: usize) -> Option<&Entry> {
        self.of_kind(Kind::Score)
            .filter(|e| e.meta.k == k && e.meta.b >= b && e.meta.t >= t)
            .min_by_key(|e| e.meta.b * e.meta.t)
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &Entry) -> String {
        format!("{}/{}", self.dir, entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "entries": [
        {"name": "score_b8_k16_t1024", "file": "score_b8_k16_t1024.hlo.txt",
         "meta": {"kind": "score", "b": 8, "k": 16, "t": 1024},
         "inputs": [{"shape": [8,16], "dtype": "f32"}, {"shape": [1024,16], "dtype": "f32"}],
         "outputs": [{"shape": [8,1024], "dtype": "f32"}],
         "golden": "golden/score_b8_k16_t1024.json"},
        {"name": "tess_ternary_n256_k16", "file": "t.hlo.txt",
         "meta": {"kind": "tess_ternary", "n": 256, "k": 16},
         "inputs": [{"shape": [256,16], "dtype": "f32"}],
         "outputs": [{"shape": [256,16], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parses_entries_and_meta() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json("arts", &j).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("score_b8_k16_t1024").unwrap();
        assert_eq!(e.kind, Kind::Score);
        assert_eq!(e.meta.b, 8);
        assert_eq!(e.meta.t, 1024);
        assert_eq!(e.inputs[1].shape, vec![1024, 16]);
        assert_eq!(e.inputs[1].elements(), 1024 * 16);
        assert_eq!(e.golden.as_deref(), Some("golden/score_b8_k16_t1024.json"));
        let t = m.entry("tess_ternary_n256_k16").unwrap();
        assert_eq!(t.kind, Kind::TessTernary);
        assert_eq!(t.meta.n, 256);
        assert!(t.golden.is_none());
        assert_eq!(m.hlo_path(e), "arts/score_b8_k16_t1024.hlo.txt");
    }

    #[test]
    fn best_scorer_selects_smallest_fit() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json("arts", &j).unwrap();
        assert!(m.best_scorer(16, 8, 1024).is_some());
        assert!(m.best_scorer(16, 9, 10).is_none(), "batch too large");
        assert!(m.best_scorer(32, 1, 1).is_none(), "no such k");
    }

    #[test]
    fn unknown_entry_is_error() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json("arts", &j).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn rejects_unknown_format() {
        let j = Json::parse(r#"{"format": "v999", "entries": []}"#).unwrap();
        assert!(Manifest::from_json("arts", &j).is_err());
    }

    #[test]
    fn loads_real_artifacts_when_present() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return; // `make artifacts` not run yet
        }
        let m = Manifest::load("artifacts").unwrap();
        assert!(m.of_kind(Kind::Score).count() >= 1);
        assert!(m.of_kind(Kind::ScoreTopk).count() >= 1);
        assert!(m.of_kind(Kind::TessTernary).count() >= 1);
    }
}
