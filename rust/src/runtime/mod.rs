//! L3 ↔ L2 bridge: load AOT artifacts (HLO text) and execute them on the
//! PJRT CPU client from the serving hot path.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py` and
//! `docs/ARCHITECTURE.md` §Runtime bridge):
//! `HloModuleProto::from_text_file` → `XlaComputation` →
//! `client.compile` once per module, then `execute` per batch.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so an [`XlaRuntime`] must stay
//! on the thread that created it. The coordinator hands each worker a
//! [`ScorerFactory`] and every worker builds its own scorer; see
//! `coordinator/worker.rs`.

mod golden;
mod manifest;
mod scorer;

pub use golden::{load_golden, verify_goldens, GoldenCase};
pub use manifest::{Entry, Kind, Manifest, MetaDims, TensorSpec};
pub use scorer::{
    cpu_scorer_factory, xla_scorer_factory, CpuScorer, Scorer, ScorerFactory,
    TopkResult, XlaScorer, MASKED_SCORE,
};

use crate::error::{GeomapError, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// A compiled AOT module bound to its manifest entry.
pub struct CompiledModule {
    exe: xla::PjRtLoadedExecutable,
    /// The manifest entry this module was compiled from.
    pub entry: Entry,
}

impl CompiledModule {
    /// Execute with positional f32 inputs given as flat row-major buffers
    /// (shapes taken from the entry). Returns the output tuple as
    /// literals, in declaration order.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.entry.inputs.len() {
            return Err(GeomapError::Shape(format!(
                "module {} wants {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&self.entry.inputs) {
            if buf.len() != spec.elements() {
                return Err(GeomapError::Shape(format!(
                    "module {}: input buffer {} != {:?}",
                    self.entry.name,
                    buf.len(),
                    spec.shape
                )));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple, even for
        // single-output modules.
        Ok(result.to_tuple()?)
    }
}

/// PJRT CPU client + per-thread compile cache over an artifact manifest.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    /// The loaded manifest.
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<CompiledModule>>>,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaRuntime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the named module.
    pub fn module(&self, name: &str) -> Result<Rc<CompiledModule>> {
        if let Some(m) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(m));
        }
        let entry = self.manifest.entry(name)?.clone();
        let path = self.manifest.hlo_path(&entry);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let module = Rc::new(CompiledModule { exe, entry });
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&module));
        Ok(module)
    }

    /// Number of modules compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Copy a (rows × cols) row-major buffer into a zero-padded
/// (pad_rows × pad_cols) buffer. Used to fit dynamic batch/tile sizes
/// into the static AOT shapes.
pub fn pad_rows(
    src: &[f32],
    rows: usize,
    cols: usize,
    pad_rows: usize,
    pad_cols: usize,
) -> Vec<f32> {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert!(pad_rows >= rows && pad_cols >= cols);
    let mut out = vec![0.0f32; pad_rows * pad_cols];
    for r in 0..rows {
        out[r * pad_cols..r * pad_cols + cols]
            .copy_from_slice(&src[r * cols..(r + 1) * cols]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_rows_pads_both_axes() {
        let src = [1.0, 2.0, 3.0, 4.0]; // 2x2
        let out = pad_rows(&src, 2, 2, 3, 4);
        assert_eq!(out.len(), 12);
        assert_eq!(&out[0..4], &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(&out[4..8], &[3.0, 4.0, 0.0, 0.0]);
        assert_eq!(&out[8..12], &[0.0; 4]);
    }

    #[test]
    fn pad_rows_identity_when_exact() {
        let src = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(pad_rows(&src, 2, 2, 2, 2), src.to_vec());
    }
}
