//! Exact rescoring backends: [`XlaScorer`] (PJRT executables from the AOT
//! artifacts) and [`CpuScorer`] (pure-rust GEMM fallback). Both implement
//! [`Scorer`]: score a query batch against an item tile and return
//! per-query top-κ (positions within the tile).

use super::{pad_rows, Kind, XlaRuntime};
use crate::error::{GeomapError, Result};
use crate::linalg::{ops::dot, Matrix};
use crate::retrieval::TopK;

/// Per-query top-κ over a tile: (tile position, exact score), descending.
pub type TopkResult = Vec<Vec<(u32, f32)>>;

/// Sentinel for masked-out columns (matches the L1 kernel's `-1e30`).
pub const MASKED_SCORE: f32 = -1e30;

/// A rescoring backend.
pub trait Scorer {
    /// Full score matrix `users · itemsᵀ` (B × T) for arbitrary B/T —
    /// backends tile internally as needed. This is what the coordinator's
    /// batched candidate-union path consumes.
    fn score(&self, users: &Matrix, items: &Matrix) -> Result<Matrix>;

    /// Whether the backend wants the worker's candidate-**union** batch
    /// GEMM (`true`: one big dispatch amortises per-call overhead — the
    /// XLA/PJRT case) or per-request candidate dots (`false`: host dots
    /// are cheapest and the union wastes flops once diverse candidate
    /// sets saturate the tile — the pure-CPU case). See EXPERIMENTS.md
    /// §Perf for the measurement behind the split.
    fn prefers_union_batching(&self) -> bool {
        true
    }

    /// Masked scoring: `S[i,j] = uᵢ·vⱼ` where `mask[j] != 0`, else a
    /// large negative sentinel (so masked columns never survive top-κ).
    /// The fused prune+score alternative to gathering candidate rows —
    /// cheap where row gathers are expensive (TPU). Default: full score
    /// + host-side mask application.
    fn score_masked(
        &self,
        users: &Matrix,
        items: &Matrix,
        mask: &[f32],
    ) -> Result<Matrix> {
        if mask.len() != items.rows() {
            return Err(GeomapError::Shape(format!(
                "mask len {} != item count {}",
                mask.len(),
                items.rows()
            )));
        }
        let mut s = self.score(users, items)?;
        for r in 0..s.rows() {
            for (v, m) in s.row_mut(r).iter_mut().zip(mask) {
                if *m == 0.0 {
                    *v = MASKED_SCORE;
                }
            }
        }
        Ok(s)
    }

    /// For each query row of `users` (B × k), the top-κ items within the
    /// `items` tile (T × k) by inner product.
    fn score_topk(&self, users: &Matrix, items: &Matrix, kappa: usize)
        -> Result<TopkResult>;

    /// Backend label for logs and reports.
    fn label(&self) -> String;
}

/// Builds a scorer on the calling thread (PJRT clients are not `Send`,
/// so each coordinator worker constructs its own backend).
pub type ScorerFactory = std::sync::Arc<dyn Fn() -> Result<Box<dyn Scorer>> + Send + Sync>;

/// Factory for the pure-rust backend.
pub fn cpu_scorer_factory() -> ScorerFactory {
    std::sync::Arc::new(|| Ok(Box::new(CpuScorer)))
}

/// Factory for the PJRT backend over an artifact directory. Scorer
/// modules are compiled eagerly at construction (worker start-up) so the
/// first request batch does not pay the XLA compile latency.
pub fn xla_scorer_factory(artifacts_dir: &str) -> ScorerFactory {
    let dir = artifacts_dir.to_string();
    std::sync::Arc::new(move || {
        let scorer = XlaScorer::load(&dir)?;
        scorer.prewarm()?;
        Ok(Box::new(scorer))
    })
}

/// Pure-rust rescoring: row-by-row dot products + a bounded heap.
pub struct CpuScorer;

impl Scorer for CpuScorer {
    fn score(&self, users: &Matrix, items: &Matrix) -> Result<Matrix> {
        if users.cols() != items.cols() {
            return Err(GeomapError::Shape(format!(
                "user k {} != item k {}",
                users.cols(),
                items.cols()
            )));
        }
        Ok(crate::linalg::ops::matmul_nt(users, items))
    }

    fn prefers_union_batching(&self) -> bool {
        false
    }

    fn score_topk(
        &self,
        users: &Matrix,
        items: &Matrix,
        kappa: usize,
    ) -> Result<TopkResult> {
        if users.cols() != items.cols() {
            return Err(GeomapError::Shape(format!(
                "user k {} != item k {}",
                users.cols(),
                items.cols()
            )));
        }
        let mut out = Vec::with_capacity(users.rows());
        for u in users.iter_rows() {
            let mut heap = TopK::new(kappa);
            for (t, v) in items.iter_rows().enumerate() {
                heap.push(t as u32, dot(u, v));
            }
            out.push(heap.into_sorted().into_iter().map(|s| (s.id, s.score)).collect());
        }
        Ok(out)
    }

    fn label(&self) -> String {
        "cpu".to_string()
    }
}

/// PJRT rescoring through the AOT `score` / `score_topk` artifacts.
///
/// Dynamic (B, T) requests are zero-padded up to the smallest artifact
/// whose static shape fits (`Manifest::best_scorer`). Zero-padded query
/// rows produce all-zero score rows that are sliced away; zero-padded
/// item rows are excluded by doing the final top-κ selection in rust over
/// the first T_real columns only. When the tile exactly matches a fused
/// `score_topk` artifact (and κ fits), the fused module is used instead —
/// one executable, no (B,T) scores materialised on the host.
pub struct XlaScorer {
    runtime: XlaRuntime,
}

impl XlaScorer {
    /// Load the artifact manifest and create the PJRT client.
    pub fn load(artifacts_dir: &str) -> Result<XlaScorer> {
        Ok(XlaScorer { runtime: XlaRuntime::load(artifacts_dir)? })
    }

    /// Access the underlying runtime (diagnostics, prewarming).
    pub fn runtime(&self) -> &XlaRuntime {
        &self.runtime
    }

    /// Compile every scorer module ahead of the first request.
    pub fn prewarm(&self) -> Result<usize> {
        let names: Vec<String> = self
            .runtime
            .manifest
            .entries
            .iter()
            .filter(|e| {
                matches!(e.kind, Kind::Score | Kind::ScoreTopk | Kind::ScoreMasked)
            })
            .map(|e| e.name.clone())
            .collect();
        for n in &names {
            self.runtime.module(n)?;
        }
        Ok(names.len())
    }

    /// Fused score+top-κ through the AOT `score_topk` artifact (exact
    /// tile-shape match required). Exposed for benches/tests; the default
    /// [`Scorer::score_topk`] path uses tiled scoring + host selection
    /// instead — on CPU PJRT the artifact's sort-based selection measures
    /// ~10× slower than the GEMM (EXPERIMENTS.md §Perf), while on a real
    /// TPU the fusion avoids the (B,T) HBM round-trip and would win.
    pub fn score_topk_fused(
        &self,
        users: &Matrix,
        items: &Matrix,
        kappa: usize,
    ) -> Result<TopkResult> {
        let name = self
            .fused_entry(users.rows(), users.cols(), items.rows(), kappa)
            .ok_or_else(|| {
                GeomapError::Artifact(format!(
                    "no fused score_topk artifact for B={} k={} T={} κ={kappa}",
                    users.rows(),
                    users.cols(),
                    items.rows()
                ))
            })?;
        self.run_fused(&name, users, items, kappa)
    }

    /// The fused path: exact-shape match against a `score_topk` artifact.
    fn fused_entry(&self, b: usize, k: usize, t: usize, kappa: usize) -> Option<String> {
        self.runtime
            .manifest
            .of_kind(Kind::ScoreTopk)
            .find(|e| {
                e.meta.k == k && e.meta.t == t && e.meta.b >= b && e.meta.kappa >= kappa
            })
            .map(|e| e.name.clone())
    }

    fn run_fused(
        &self,
        name: &str,
        users: &Matrix,
        items: &Matrix,
        kappa: usize,
    ) -> Result<TopkResult> {
        let module = self.runtime.module(name)?;
        let m = module.entry.meta;
        let u = pad_rows(users.as_slice(), users.rows(), users.cols(), m.b, m.k);
        let outs = module.run_f32(&[&u, items.as_slice()])?;
        let values = outs[0].to_vec::<f32>()?;
        let indices = outs[1].to_vec::<i32>()?;
        let width = m.kappa;
        let mut result = Vec::with_capacity(users.rows());
        for b in 0..users.rows() {
            let row: Vec<(u32, f32)> = (0..kappa.min(width))
                .map(|j| {
                    (indices[b * width + j] as u32, values[b * width + j])
                })
                .collect();
            result.push(row);
        }
        Ok(result)
    }

    /// Masked scoring through the AOT `score_masked` artifact, tiled for
    /// arbitrary (B, T). Falls back to the trait default (score + host
    /// mask) when no masked artifact matches this k.
    fn score_masked_xla(
        &self,
        users: &Matrix,
        items: &Matrix,
        mask: &[f32],
    ) -> Result<Option<Matrix>> {
        let (b, k, t) = (users.rows(), users.cols(), items.rows());
        let entry = match self
            .runtime
            .manifest
            .of_kind(Kind::ScoreMasked)
            .filter(|e| e.meta.k == k)
            .max_by_key(|e| e.meta.b * e.meta.t)
        {
            Some(e) => e.name.clone(),
            None => return Ok(None),
        };
        let module = self.runtime.module(&entry)?;
        let m = module.entry.meta;
        let mut out = Matrix::zeros(b, t);
        for b0 in (0..b).step_by(m.b) {
            let b1 = (b0 + m.b).min(b);
            let ublock = users.slice_rows(b0, b1);
            let u = pad_rows(ublock.as_slice(), b1 - b0, k, m.b, m.k);
            for t0 in (0..t).step_by(m.t) {
                let t1 = (t0 + m.t).min(t);
                let vblock = items.slice_rows(t0, t1);
                let v = pad_rows(vblock.as_slice(), t1 - t0, k, m.t, m.k);
                let mut mk = vec![0.0f32; m.t];
                mk[..t1 - t0].copy_from_slice(&mask[t0..t1]);
                let outs = module.run_f32(&[&u, &v, &mk])?;
                let scores = outs[0].to_vec::<f32>()?;
                for r in b0..b1 {
                    let src = &scores[(r - b0) * m.t..(r - b0) * m.t + (t1 - t0)];
                    out.row_mut(r)[t0..t1].copy_from_slice(src);
                }
            }
        }
        Ok(Some(out))
    }

    fn run_padded(
        &self,
        users: &Matrix,
        items: &Matrix,
        kappa: usize,
    ) -> Result<TopkResult> {
        // the tiled full-score path handles any (B, T); top-κ selection
        // over the exact scores happens host-side.
        let scores = self.score(users, items)?;
        let mut result = Vec::with_capacity(users.rows());
        for row in 0..users.rows() {
            let mut heap = TopK::new(kappa);
            for (col, &s) in scores.row(row).iter().enumerate() {
                heap.push(col as u32, s);
            }
            result.push(
                heap.into_sorted().into_iter().map(|s| (s.id, s.score)).collect(),
            );
        }
        Ok(result)
    }
}

impl Scorer for XlaScorer {
    fn score(&self, users: &Matrix, items: &Matrix) -> Result<Matrix> {
        let (b, k, t) = (users.rows(), users.cols(), items.rows());
        if k != items.cols() {
            return Err(GeomapError::Shape(format!(
                "user k {k} != item k {}",
                items.cols()
            )));
        }
        // the largest score artifact for this k defines the tile grid
        let entry = self
            .runtime
            .manifest
            .of_kind(Kind::Score)
            .filter(|e| e.meta.k == k)
            .max_by_key(|e| e.meta.b * e.meta.t)
            .ok_or_else(|| {
                GeomapError::Artifact(format!("no score artifact for k={k}"))
            })?
            .name
            .clone();
        let module = self.runtime.module(&entry)?;
        let m = module.entry.meta;
        let mut out = Matrix::zeros(b, t);
        for b0 in (0..b).step_by(m.b) {
            let b1 = (b0 + m.b).min(b);
            let ublock = users.slice_rows(b0, b1);
            let u = pad_rows(ublock.as_slice(), b1 - b0, k, m.b, m.k);
            for t0 in (0..t).step_by(m.t) {
                let t1 = (t0 + m.t).min(t);
                let vblock = items.slice_rows(t0, t1);
                let v = pad_rows(vblock.as_slice(), t1 - t0, k, m.t, m.k);
                let outs = module.run_f32(&[&u, &v])?;
                let scores = outs[0].to_vec::<f32>()?;
                for r in b0..b1 {
                    let src = &scores[(r - b0) * m.t..(r - b0) * m.t + (t1 - t0)];
                    out.row_mut(r)[t0..t1].copy_from_slice(src);
                }
            }
        }
        Ok(out)
    }

    fn score_masked(
        &self,
        users: &Matrix,
        items: &Matrix,
        mask: &[f32],
    ) -> Result<Matrix> {
        if mask.len() != items.rows() {
            return Err(GeomapError::Shape(format!(
                "mask len {} != item count {}",
                mask.len(),
                items.rows()
            )));
        }
        if let Some(s) = self.score_masked_xla(users, items, mask)? {
            return Ok(s);
        }
        // no masked artifact for this k: trait-default path
        let mut s = self.score(users, items)?;
        for r in 0..s.rows() {
            for (v, m) in s.row_mut(r).iter_mut().zip(mask) {
                if *m == 0.0 {
                    *v = MASKED_SCORE;
                }
            }
        }
        Ok(s)
    }

    fn score_topk(
        &self,
        users: &Matrix,
        items: &Matrix,
        kappa: usize,
    ) -> Result<TopkResult> {
        if users.cols() != items.cols() {
            return Err(GeomapError::Shape(format!(
                "user k {} != item k {}",
                users.cols(),
                items.cols()
            )));
        }
        // tiled GEMM + host-side selection; see score_topk_fused for the
        // AOT-fused alternative and the §Perf measurement behind this
        // default.
        self.run_padded(users, items, kappa)
    }

    fn label(&self) -> String {
        format!("xla({})", self.runtime.platform())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn factors(rows: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seeded(seed);
        Matrix::gaussian(&mut rng, rows, k, 1.0)
    }

    #[test]
    fn cpu_scorer_matches_brute_force() {
        let users = factors(4, 8, 1);
        let items = factors(50, 8, 2);
        let got = CpuScorer.score_topk(&users, &items, 5).unwrap();
        assert_eq!(got.len(), 4);
        for (u, row) in got.iter().enumerate() {
            assert_eq!(row.len(), 5);
            let brute = crate::retrieval::brute_force_top_k(
                users.row(u),
                &items,
                5,
            );
            for (g, b) in row.iter().zip(&brute) {
                assert_eq!(g.0, b.id);
                assert!((g.1 - b.score).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn cpu_scorer_rejects_dim_mismatch() {
        let users = factors(2, 8, 3);
        let items = factors(10, 4, 4);
        assert!(CpuScorer.score_topk(&users, &items, 3).is_err());
    }

    #[test]
    fn kappa_larger_than_tile_is_truncated() {
        let users = factors(1, 4, 5);
        let items = factors(3, 4, 6);
        let got = CpuScorer.score_topk(&users, &items, 10).unwrap();
        assert_eq!(got[0].len(), 3);
    }

    // XlaScorer end-to-end tests live in rust/tests/xla_runtime.rs (they
    // need the artifacts directory built by `make artifacts`).
}
