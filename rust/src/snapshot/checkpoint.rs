//! Background checkpointing of a serving catalogue.
//!
//! A [`Checkpointer`] thread periodically snapshots a
//! [`FactorStore`](crate::coordinator::FactorStore) when — and only
//! when — its catalogue version changed since the last checkpoint.
//! Writes are crash-safe (the store writes `<path>.tmp` and renames into
//! place, so a reader never observes a half-written file) and retention
//! is bounded: after every successful checkpoint all but the newest
//! `keep_last` snapshots are pruned. A final checkpoint is taken on
//! clean [`stop`](Checkpointer::stop), so shutdown never loses acked
//! mutations.
//!
//! Snapshot files are named `snapshot-v<version>.gsnp` with the version
//! zero-padded, so lexicographic and version order agree.

use crate::configx::CheckpointConfig;
use crate::coordinator::FactorStore;
use crate::error::{GeomapError, Result};
use crate::obs::Logger;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

static LOG: Logger = Logger::new("checkpoint");

/// File name of the checkpoint for catalogue version `v`.
pub fn snapshot_file(dir: &str, version: u64) -> String {
    format!("{dir}/snapshot-v{version:020}.gsnp")
}

fn parse_version(name: &str) -> Option<u64> {
    name.strip_prefix("snapshot-v")?.strip_suffix(".gsnp")?.parse().ok()
}

/// Catalogue version encoded in a checkpoint path, if it is one.
pub fn version_of(path: &str) -> Option<u64> {
    parse_version(path.rsplit('/').next()?)
}

/// Newest checkpoint in `dir` (by catalogue version), if any.
pub fn latest_snapshot(dir: &str) -> Result<Option<String>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(GeomapError::io(dir, e)),
    };
    let mut best: Option<(u64, String)> = None;
    for entry in entries {
        let entry = entry.map_err(|e| GeomapError::io(dir, e))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(v) = parse_version(&name) {
            let path = format!("{dir}/{name}");
            if best.as_ref().map_or(true, |(bv, _)| v > *bv) {
                best = Some((v, path));
            }
        }
    }
    Ok(best.map(|(_, p)| p))
}

/// Delete all but the newest `keep_last` checkpoints in `dir`, plus any
/// `snapshot-v*.gsnp.tmp` left behind by a failed or interrupted write
/// (the writer is single-threaded, so no checkpoint write is in flight
/// while pruning runs).
fn prune(dir: &str, keep_last: usize) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut versions: Vec<(u64, String)> = Vec::new();
    for e in entries.flatten() {
        let name = e.file_name().to_string_lossy().into_owned();
        if let Some(v) = parse_version(&name) {
            versions.push((v, format!("{dir}/{name}")));
        } else if name.starts_with("snapshot-")
            && (name.ends_with(".gsnp.tmp") || name == "snapshot-inflight.gsnp")
        {
            // leftovers of a write that crashed before publishing
            if let Err(e) = std::fs::remove_file(format!("{dir}/{name}")) {
                LOG.warn(format!("removing stale {name} failed: {e}"));
            }
        }
    }
    versions.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    for (v, path) in versions.into_iter().skip(keep_last) {
        if let Err(e) = std::fs::remove_file(&path) {
            LOG.warn(format!("pruning snapshot v{v} failed: {e}"));
        }
    }
}

fn checkpoint_if_changed(
    cfg: &CheckpointConfig,
    store: &FactorStore,
    last_saved: &mut Option<u64>,
) {
    let version = store.snapshot().version;
    if *last_saved == Some(version) {
        return;
    }
    // the save re-snapshots the store, so a mutation landing after the
    // version probe would make a pre-computed file name lie about the
    // content: write under a provisional name first, then rename to the
    // version the save actually captured
    let provisional = format!("{}/snapshot-inflight.gsnp", cfg.dir);
    match store.save_snapshot(&provisional) {
        Ok(saved) => {
            let path = snapshot_file(&cfg.dir, saved);
            if let Err(e) = std::fs::rename(&provisional, &path) {
                LOG.error(format!("publishing checkpoint v{saved} failed: {e}"));
                return;
            }
            LOG.info(format!("checkpointed catalogue v{saved} → {path}"));
            *last_saved = Some(saved);
            prune(&cfg.dir, cfg.keep_last);
        }
        Err(e) => LOG.error(format!("checkpoint of v{version} failed: {e}")),
    }
}

/// Handle of the background checkpoint thread.
pub struct Checkpointer {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Checkpointer {
    /// Spawn the checkpoint thread over `store` with policy `cfg`.
    pub fn spawn(cfg: CheckpointConfig, store: Arc<FactorStore>) -> Checkpointer {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("geomap-checkpoint".into())
            .spawn(move || {
                // seed from the newest on-disk checkpoint so a
                // warm-started coordinator does not immediately rewrite
                // the very snapshot it just loaded
                let mut last_saved: Option<u64> = latest_snapshot(&cfg.dir)
                    .ok()
                    .flatten()
                    .and_then(|p| parse_version(p.rsplit('/').next()?));
                let tick = Duration::from_millis(cfg.every_ms.min(20).max(1));
                let mut waited = Duration::ZERO;
                while !flag.load(Ordering::Acquire) {
                    std::thread::sleep(tick);
                    waited += tick;
                    if waited.as_millis() as u64 >= cfg.every_ms {
                        waited = Duration::ZERO;
                        checkpoint_if_changed(&cfg, &store, &mut last_saved);
                    }
                }
                // final checkpoint so a clean shutdown loses nothing
                checkpoint_if_changed(&cfg, &store, &mut last_saved);
            })
            .expect("spawn checkpointer");
        Checkpointer { stop, handle: Some(handle) }
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Stop the thread after one final checkpoint (blocking).
    pub fn stop(mut self) {
        self.stop_inner();
    }
}

impl Drop for Checkpointer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    fn unique_dir(name: &str) -> String {
        let dir = std::env::temp_dir()
            .join("geomap-checkpoint-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    fn store(n: usize) -> Arc<FactorStore> {
        let mut rng = Rng::seeded(11);
        let items = Matrix::gaussian(&mut rng, n, 8, 1.0);
        Arc::new(FactorStore::build(Engine::builder(), items, 2).unwrap())
    }

    #[test]
    fn naming_roundtrip_and_latest() {
        let dir = unique_dir("naming");
        assert_eq!(parse_version("snapshot-v00000000000000000042.gsnp"), Some(42));
        assert_eq!(parse_version("other.gsnp"), None);
        assert_eq!(latest_snapshot(&dir).unwrap(), None);
        assert_eq!(latest_snapshot("/definitely/missing/dir").unwrap(), None);
        let s = store(40);
        s.save_snapshot(&snapshot_file(&dir, 1)).unwrap();
        s.save_snapshot(&snapshot_file(&dir, 12)).unwrap();
        s.save_snapshot(&snapshot_file(&dir, 3)).unwrap();
        assert_eq!(
            latest_snapshot(&dir).unwrap().unwrap(),
            snapshot_file(&dir, 12)
        );
    }

    #[test]
    fn prune_keeps_newest() {
        let dir = unique_dir("prune");
        let s = store(30);
        for v in [1u64, 2, 3, 4, 5] {
            s.save_snapshot(&snapshot_file(&dir, v)).unwrap();
        }
        // a failed write's leftover must be reclaimed too
        std::fs::write(format!("{dir}/snapshot-v9.gsnp.tmp"), b"junk").unwrap();
        prune(&dir, 2);
        let mut left: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        left.sort();
        assert_eq!(
            left,
            vec![
                "snapshot-v00000000000000000004.gsnp".to_string(),
                "snapshot-v00000000000000000005.gsnp".to_string(),
            ]
        );
    }

    #[test]
    fn checkpointer_saves_on_change_and_on_stop() {
        let dir = unique_dir("ckpt");
        let s = store(50);
        let ck = Checkpointer::spawn(
            CheckpointConfig { dir: dir.clone(), every_ms: 10, keep_last: 2 },
            Arc::clone(&s),
        );
        // wait for the first periodic checkpoint (version 1)
        let mut waited = 0;
        while latest_snapshot(&dir).unwrap().is_none() && waited < 2000 {
            std::thread::sleep(Duration::from_millis(10));
            waited += 10;
        }
        assert!(
            latest_snapshot(&dir).unwrap().is_some(),
            "no checkpoint within 2s"
        );
        // mutate, then stop: the final checkpoint must capture the new
        // version even if the periodic timer never fired again
        s.upsert(50, &[0.5; 8]).unwrap();
        let v = s.snapshot().version;
        ck.stop();
        let latest = latest_snapshot(&dir).unwrap().unwrap();
        assert_eq!(latest, snapshot_file(&dir, v));
        // retention: at most keep_last files remain
        let count = std::fs::read_dir(&dir).unwrap().flatten().count();
        assert!(count <= 2, "{count} snapshots left, want <= 2");
        // and it restores
        let restored = FactorStore::from_snapshot(&latest).unwrap();
        assert_eq!(restored.snapshot().version, v);
        assert_eq!(restored.snapshot().total_items, 51);
    }
}
