//! Engine ⇄ snapshot-section codec.
//!
//! One engine serialises to a group of sections sharing a shard ordinal:
//!
//! * `config`   — the full [`EngineBuilder`] spec as JSON (round-trips
//!   through `configx::Backend::parse` / `SchemaConfig::parse`).
//! * `factors`  — the dense catalogue factors (for the geomap backend,
//!   the *base segment* factors in row order).
//! * `index` / `base-map` / `delta` — geomap backend only: the CSR
//!   inverted index, the id ↔ row mapping with its tombstone bitmap, and
//!   the pending-mutation delta segment.
//!
//! Loading a geomap engine reassembles this state directly — no φ
//! re-mapping, no per-posting parsing — which is the snapshot
//! subsystem's whole point: the expensive offline work is paid once.
//! Baseline backends (SRP/Superbit/CROS/PCA-tree/brute) are rebuilt
//! deterministically from factors + the stored seed, so a loaded engine
//! is bit-identical to a rebuilt one for every backend.
//!
//! All decoded shapes are cross-validated; a corrupt section that
//! somehow passes its CRC still fails loudly here.

use super::format::{
    cast_f32s, cast_u32s, push_f32s, push_u32s, Cursor, Reader, SectionKind,
    Writer,
};
use crate::configx::{
    obj, Backend, Json, MutationConfig, PostingsMode, QuantMode, SchemaConfig,
};
use crate::embedding::Mapper;
use crate::engine::{BaseSegment, DeltaSegment, Engine, EngineBuilder, GeomapEngine};
use crate::error::{GeomapError, Result};
use crate::index::InvertedIndex;
use crate::linalg::Matrix;
use crate::quant::{PackedPostings, QuantizedFactorStore};
use std::collections::HashMap;
use std::sync::Arc;

// ------------------------------------------------------------ spec json

/// Serialise a build spec to the `config` section JSON.
pub fn spec_to_json(spec: &EngineBuilder) -> Json {
    obj(vec![
        ("backend", Json::from(spec.backend.spec())),
        ("schema", Json::from(spec.schema.spec())),
        ("threshold", Json::from(spec.threshold as f64)),
        ("min_overlap", Json::from(spec.min_overlap)),
        // the seed is a full u64; JSON numbers are f64, so keep it exact
        // as a decimal string
        ("seed", Json::from(spec.seed.to_string())),
        ("max_delta", Json::from(spec.mutation.max_delta)),
        ("quant", Json::from(spec.quant.spec())),
        ("postings", Json::from(spec.postings.spec())),
    ])
}

/// Parse a `config` section back into a build spec.
pub fn spec_from_json(j: &Json) -> Result<EngineBuilder> {
    let backend = Backend::parse(j.get("backend")?.as_str()?)?;
    let schema = SchemaConfig::parse(j.get("schema")?.as_str()?)?;
    let threshold = j.get("threshold")?.as_f64()? as f32;
    let min_overlap = j.get("min_overlap")?.as_usize()?;
    let seed: u64 = j.get("seed")?.as_str()?.parse().map_err(|_| {
        GeomapError::Artifact("snapshot config has a malformed seed".into())
    })?;
    let max_delta = j.get("max_delta")?.as_usize()?;
    // quant/postings arrived with format v2; absent keys (a v1 snapshot)
    // mean the pre-quantization defaults
    let quant = match j.opt("quant") {
        Some(v) => QuantMode::parse(v.as_str()?)?,
        None => QuantMode::Off,
    };
    let postings = match j.opt("postings") {
        Some(v) => PostingsMode::parse(v.as_str()?)?,
        None => PostingsMode::Raw,
    };
    Ok(Engine::builder()
        .backend(backend)
        .schema(schema)
        .threshold(threshold)
        .min_overlap(min_overlap)
        .seed(seed)
        .mutation(MutationConfig { max_delta })
        .quant(quant)
        .postings(postings))
}

// -------------------------------------------------------------- bitmaps

fn push_bitmap(buf: &mut Vec<u8>, bits: &[bool]) {
    let mut byte = 0u8;
    for (i, &b) in bits.iter().enumerate() {
        if b {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            buf.push(byte);
            byte = 0;
        }
    }
    if bits.len() % 8 != 0 {
        buf.push(byte);
    }
}

fn read_bitmap(bytes: &[u8], n: usize) -> Vec<bool> {
    (0..n).map(|i| bytes[i / 8] & (1 << (i % 8)) != 0).collect()
}

// --------------------------------------------------------------- encode

fn push_i8s(buf: &mut Vec<u8>, xs: &[i8]) {
    // SAFETY: i8 and u8 are layout-identical; reading i8s as bytes is
    // always valid.
    let raw = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len())
    };
    buf.extend_from_slice(raw);
}

/// Write one engine as the section group of shard ordinal `shard`.
pub fn write_engine(w: &mut Writer, shard: u16, engine: &Engine) -> Result<()> {
    let spec = engine.spec();
    let buf = w.begin();
    buf.extend_from_slice(spec_to_json(&spec).to_string_compact().as_bytes());
    w.end(SectionKind::Config, shard)?;

    if let Some(g) = engine.geomap_source() {
        write_geomap(w, shard, g)?;
        // the quantized tier rides along so a geomap load never
        // requantizes; the section raises the container format to v2.
        // Baseline backends skip it: their load path rebuilds from
        // factors anyway (deterministically, bit-identical codes), so
        // writing the section would only bloat the file and cost the
        // snapshot its v1 readability.
        if let Some(q) = engine.quant_store() {
            let buf = w.begin();
            buf.extend_from_slice(&(q.len() as u64).to_le_bytes());
            buf.extend_from_slice(&(q.k() as u64).to_le_bytes());
            push_f32s(buf, q.scales());
            push_i8s(buf, q.codes());
            w.end(SectionKind::Quant, shard)?;
        }
    } else {
        let factors = engine.dense_factors().ok_or_else(|| {
            GeomapError::Config(format!(
                "backend '{}' exposes no dense factors to snapshot",
                spec.backend.spec()
            ))
        })?;
        write_factors(w, shard, factors)?;
    }
    Ok(())
}

fn write_factors(w: &mut Writer, shard: u16, m: &Matrix) -> Result<()> {
    let buf = w.begin();
    buf.extend_from_slice(&(m.rows() as u64).to_le_bytes());
    buf.extend_from_slice(&(m.cols() as u64).to_le_bytes());
    push_f32s(buf, m.as_slice());
    w.end(SectionKind::Factors, shard)
}

fn write_geomap(w: &mut Writer, shard: u16, g: &GeomapEngine) -> Result<()> {
    let base = &g.base;
    write_factors(w, shard, &base.items)?;

    // index: the arena verbatim — raw CSR or the packed block tables
    let idx = &base.index;
    match idx.packed() {
        None => {
            let offsets = idx.offsets_arena().expect("raw arena");
            let postings = idx.postings_arena().expect("raw arena");
            let buf = w.begin();
            buf.extend_from_slice(&(idx.items() as u64).to_le_bytes());
            buf.extend_from_slice(&(idx.dim() as u64).to_le_bytes());
            buf.extend_from_slice(&(offsets.len() as u64).to_le_bytes());
            buf.extend_from_slice(&(postings.len() as u64).to_le_bytes());
            push_u32s(buf, offsets);
            push_u32s(buf, postings);
            w.end(SectionKind::Index, shard)?;
        }
        Some(pk) => {
            let (dofs, bwords, bfirst, bmax, binfo, words) = pk.arenas();
            let buf = w.begin();
            buf.extend_from_slice(&(pk.items() as u64).to_le_bytes());
            buf.extend_from_slice(&(pk.dims() as u64).to_le_bytes());
            buf.extend_from_slice(&(pk.total() as u64).to_le_bytes());
            buf.extend_from_slice(&(pk.blocks() as u64).to_le_bytes());
            buf.extend_from_slice(&(words.len() as u64).to_le_bytes());
            push_u32s(buf, dofs);
            push_u32s(buf, bwords);
            push_u32s(buf, bfirst);
            push_u32s(buf, bmax);
            push_u32s(buf, binfo);
            push_u32s(buf, words);
            w.end(SectionKind::PackedIndex, shard)?;
        }
    }

    // base map: id mapping + liveness. An identity base keeps no
    // materialised maps in memory, so they are synthesised here — the
    // on-disk layout is identical either way. `row_of` only spans the
    // address space as of the last merge; ids appended since then live
    // in the delta, so the serialised map is padded to `addr` entries
    // (the pad value, u32::MAX, means "no base row" — exactly what the
    // runtime lookup concludes for an out-of-range id).
    let n_rows = base.rows();
    let ident_buf: Vec<u32>;
    let (ids, row_of): (&[u32], &[u32]) = if base.identity {
        ident_buf = (0..n_rows as u32).collect();
        (&ident_buf, &ident_buf)
    } else {
        (&base.ids, &base.row_of)
    };
    let buf = w.begin();
    buf.extend_from_slice(&(g.addr as u64).to_le_bytes());
    buf.extend_from_slice(&(n_rows as u64).to_le_bytes());
    buf.extend_from_slice(&(g.live as u64).to_le_bytes());
    buf.extend_from_slice(&(g.dead_rows as u64).to_le_bytes());
    buf.push(base.identity as u8);
    buf.extend_from_slice(&[0u8; 7]);
    push_u32s(buf, ids);
    push_u32s(buf, row_of);
    for _ in row_of.len()..g.addr {
        push_u32s(buf, &[u32::MAX]);
    }
    push_bitmap(buf, &g.base_dead);
    w.end(SectionKind::BaseMap, shard)?;

    // delta segment: pending upserts (+ per-dimension posting pairs,
    // dims sorted for deterministic bytes, row order preserved)
    let d = &g.delta;
    let mut dims: Vec<u32> = d.postings.keys().copied().collect();
    dims.sort_unstable();
    let n_pairs: usize = d.postings.values().map(Vec::len).sum();
    let buf = w.begin();
    buf.extend_from_slice(&(d.k as u64).to_le_bytes());
    buf.extend_from_slice(&(d.ids.len() as u64).to_le_bytes());
    buf.extend_from_slice(&(d.nnz as u64).to_le_bytes());
    buf.extend_from_slice(&(n_pairs as u64).to_le_bytes());
    push_f32s(buf, &d.factors);
    push_u32s(buf, &d.ids);
    for dim in dims {
        for &dr in &d.postings[&dim] {
            push_u32s(buf, &[dim, dr]);
        }
    }
    push_bitmap(buf, &d.alive);
    w.end(SectionKind::Delta, shard)
}

// --------------------------------------------------------------- decode

/// Read the `config` section of `shard` as a build spec.
pub fn read_spec(r: &Reader, shard: u16) -> Result<EngineBuilder> {
    let bytes = r.section(SectionKind::Config, shard)?;
    let text = std::str::from_utf8(bytes).map_err(|_| {
        GeomapError::Artifact("snapshot config section is not UTF-8".into())
    })?;
    spec_from_json(&Json::parse(text)?)
}

/// Reassemble the engine of shard ordinal `shard`.
pub fn read_engine(r: &Reader, shard: u16) -> Result<Engine> {
    let spec = read_spec(r, shard)?;
    let factors = read_factors(r, shard)?;
    if spec.backend != Backend::Geomap {
        // baselines rebuild deterministically from factors + stored seed
        // (quantization is deterministic too, so the rebuilt int8 tier
        // is bit-identical to the one the snapshot carries)
        return spec.build(factors);
    }
    let g = read_geomap(r, shard, &spec, factors)?;
    let quant = if spec.quant.is_on() {
        Some(read_quant(r, shard, g.addr, g.delta.k)?)
    } else {
        None
    };
    Ok(Engine::from_parts(spec, Box::new(g), quant))
}

/// Read and cross-validate the `quant` section of `shard`: the stored
/// tier must mirror the engine's id space (`len`) and dimensionality.
fn read_quant(
    r: &Reader,
    shard: u16,
    len: usize,
    k: usize,
) -> Result<QuantizedFactorStore> {
    let bytes = r.section(SectionKind::Quant, shard)?;
    let mut c = Cursor::new(bytes, "quant");
    let n = c.count("item")?;
    let qk = c.count("factor dim")?;
    let scales = cast_f32s(c.take(n.checked_mul(4).ok_or_else(|| {
        GeomapError::Artifact("quant scale payload overflows".into())
    })?)?)?;
    let n_codes = n.checked_mul(qk).ok_or_else(|| {
        GeomapError::Artifact("quant code payload overflows".into())
    })?;
    let codes: Vec<i8> = c.take(n_codes)?.iter().map(|&b| b as i8).collect();
    c.done()?;
    if n != len || qk != k {
        return Err(GeomapError::Artifact(format!(
            "quant tier covers {n} items of dim {qk} but the engine has \
             {len} of dim {k}"
        )));
    }
    QuantizedFactorStore::from_parts(qk, codes, scales)
}

fn read_factors(r: &Reader, shard: u16) -> Result<Matrix> {
    let bytes = r.section(SectionKind::Factors, shard)?;
    let mut c = Cursor::new(bytes, "factors");
    let rows = c.count("row")?;
    let cols = c.count("col")?;
    let n = rows.checked_mul(cols).and_then(|n| n.checked_mul(4)).ok_or_else(
        || {
            GeomapError::Artifact(format!(
                "factors section dims {rows}x{cols} overflow"
            ))
        },
    )?;
    let data = cast_f32s(c.take(n)?)?;
    c.done()?;
    Matrix::from_vec(rows, cols, data)
}

fn read_geomap(
    r: &Reader,
    shard: u16,
    spec: &EngineBuilder,
    items: Matrix,
) -> Result<GeomapEngine> {
    let k = items.cols();
    let mapper = Mapper::from_config(spec.schema, k, spec.threshold);

    // index: the section kind follows the spec's postings mode (a
    // missing section means the snapshot disagrees with its own config)
    let (index, idx_items, p) = match spec.postings {
        PostingsMode::Raw => {
            let bytes = r.section(SectionKind::Index, shard)?;
            let mut c = Cursor::new(bytes, "index");
            let idx_items = c.count("item")?;
            let p = c.count("dimension")?;
            let n_offsets = c.count("offset")?;
            let n_postings = c.count("posting")?;
            let offsets = cast_u32s(c.take(n_offsets * 4)?)?;
            let postings = cast_u32s(c.take(n_postings * 4)?)?;
            c.done()?;
            let index =
                InvertedIndex::from_raw_parts(offsets, postings, idx_items, p)?;
            (index, idx_items, p)
        }
        PostingsMode::Packed => {
            let bytes = r.section(SectionKind::PackedIndex, shard)?;
            let mut c = Cursor::new(bytes, "packed-index");
            let idx_items = c.count("item")?;
            let p = c.count("dimension")?;
            let total = c.count("posting")?;
            let n_blocks = c.count("block")?;
            let n_words = c.count("word")?;
            let dofs = cast_u32s(c.take((p + 1) * 4)?)?;
            let bwords = cast_u32s(c.take(n_blocks * 4)?)?;
            let bfirst = cast_u32s(c.take(n_blocks * 4)?)?;
            let bmax = cast_u32s(c.take(n_blocks * 4)?)?;
            let binfo = cast_u32s(c.take(n_blocks * 4)?)?;
            let words = cast_u32s(c.take(n_words * 4)?)?;
            c.done()?;
            let pk = PackedPostings::from_parts(
                p, idx_items, total, dofs, bwords, bfirst, bmax, binfo, words,
            )?;
            (InvertedIndex::from_packed(pk), idx_items, p)
        }
    };
    if idx_items != items.rows() {
        return Err(GeomapError::Artifact(format!(
            "index covers {idx_items} items but factors have {}",
            items.rows()
        )));
    }
    if p != mapper.p() {
        return Err(GeomapError::Artifact(format!(
            "index dimension {p} does not match schema '{}' (p = {})",
            spec.schema.spec(),
            mapper.p()
        )));
    }

    // base map
    let bytes = r.section(SectionKind::BaseMap, shard)?;
    let mut c = Cursor::new(bytes, "base-map");
    let addr = c.count("address")?;
    let n_rows = c.count("base row")?;
    let live = c.count("live item")?;
    let dead_rows = c.count("tombstone")?;
    let identity = c.u8()? != 0;
    c.take(7)?; // padding
    let ids = cast_u32s(c.take(n_rows * 4)?)?;
    let row_of = cast_u32s(c.take(addr * 4)?)?;
    let base_dead = read_bitmap(c.take(n_rows.div_ceil(8))?, n_rows);
    c.done()?;

    if n_rows != items.rows() {
        return Err(GeomapError::Artifact(format!(
            "base map covers {n_rows} rows but factors have {}",
            items.rows()
        )));
    }
    for (row, w) in ids.windows(2).enumerate() {
        if w[0] >= w[1] {
            return Err(GeomapError::Artifact(format!(
                "base ids not strictly increasing at row {row}"
            )));
        }
    }
    for (row, &id) in ids.iter().enumerate() {
        if (id as usize) >= addr || row_of[id as usize] != row as u32 {
            return Err(GeomapError::Artifact(format!(
                "base id {id} / row {row} mapping is inconsistent"
            )));
        }
    }
    // identity (the dense-factor fast-path gate) asserts base row r
    // holds id r with no holes as of the last merge. Appends since then
    // raise `addr` without touching the base, and trailing removals can
    // legitimately clear the flag while ids still read 0..len — so the
    // flag is validated one-directionally here (true ⇒ ids are 0..len)
    // and against the delta below (true ⇒ every id past the base is a
    // pending append). A cleared flag is conservative and safe.
    if identity && !ids.iter().enumerate().all(|(row, &id)| id as usize == row)
    {
        return Err(GeomapError::Artifact(
            "base identity flag disagrees with the id map".into(),
        ));
    }
    let mapped = row_of.iter().filter(|&&r| r != u32::MAX).count();
    if mapped != ids.len() {
        return Err(GeomapError::Artifact(format!(
            "base row map addresses {mapped} rows but {} exist",
            ids.len()
        )));
    }
    if base_dead.iter().filter(|&&d| d).count() != dead_rows {
        return Err(GeomapError::Artifact(
            "tombstone bitmap disagrees with the stored tombstone count".into(),
        ));
    }

    // delta segment
    let bytes = r.section(SectionKind::Delta, shard)?;
    let mut c = Cursor::new(bytes, "delta");
    let dk = c.count("factor dim")?;
    let d_rows = c.count("delta row")?;
    let nnz = c.count("non-zero")?;
    let n_pairs = c.count("posting pair")?;
    let d_bytes = d_rows
        .checked_mul(dk)
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| {
            GeomapError::Artifact("delta factor payload overflows".into())
        })?;
    let d_factors = cast_f32s(c.take(d_bytes)?)?;
    let d_ids = cast_u32s(c.take(d_rows * 4)?)?;
    let pairs = cast_u32s(c.take(n_pairs * 8)?)?;
    let alive = read_bitmap(c.take(d_rows.div_ceil(8))?, d_rows);
    c.done()?;

    if dk != k {
        return Err(GeomapError::Artifact(format!(
            "delta factor dim {dk} != catalogue dim {k}"
        )));
    }
    if d_ids.iter().any(|&id| id as usize >= addr) {
        return Err(GeomapError::Artifact(
            "delta references an id beyond the address space".into(),
        ));
    }
    if nnz != n_pairs {
        return Err(GeomapError::Artifact(format!(
            "delta nnz {nnz} disagrees with its {n_pairs} posting pairs"
        )));
    }
    let mut d_postings: HashMap<u32, Vec<u32>> = HashMap::new();
    for pair in pairs.chunks_exact(2) {
        let (dim, dr) = (pair[0], pair[1]);
        if dim as usize >= p || dr as usize >= d_rows {
            return Err(GeomapError::Artifact(format!(
                "delta posting ({dim}, {dr}) is out of bounds"
            )));
        }
        let rows = d_postings.entry(dim).or_default();
        // rows are created in increasing order and each row's support
        // lists a dimension once, so per-dim rows are strictly
        // increasing; a duplicate would double-count overlap at query
        // time and must be rejected
        if rows.last().is_some_and(|&prev| prev >= dr) {
            return Err(GeomapError::Artifact(format!(
                "delta posting list for dim {dim} is not strictly \
                 increasing at row {dr}"
            )));
        }
        rows.push(dr);
    }
    let mut d_row_of: HashMap<u32, u32> = HashMap::new();
    for (dr, (&id, &is_alive)) in d_ids.iter().zip(&alive).enumerate() {
        if is_alive && d_row_of.insert(id, dr as u32).is_some() {
            return Err(GeomapError::Artifact(format!(
                "delta has two live rows for id {id}"
            )));
        }
    }
    let alive_count = d_row_of.len();
    if live != (n_rows - dead_rows) + alive_count {
        return Err(GeomapError::Artifact(format!(
            "live count {live} disagrees with segments \
             ({n_rows} base - {dead_rows} dead + {alive_count} delta)"
        )));
    }
    // a live delta row supersedes any base copy of the same id, so the
    // base row must be tombstoned
    for &id in d_row_of.keys() {
        if let Some(&row) = row_of.get(id as usize) {
            if row != u32::MAX && !base_dead[row as usize] {
                return Err(GeomapError::Artifact(format!(
                    "id {id} is live in both the base and the delta"
                )));
            }
        }
    }
    // identity accounting across segments: with the flag set, every id
    // beyond the base must be a pending append (present in the delta) —
    // otherwise the dense fast path could address missing rows
    if identity && n_rows < addr {
        let delta_ids: std::collections::HashSet<u32> =
            d_ids.iter().copied().collect();
        for id in n_rows as u32..addr as u32 {
            if !delta_ids.contains(&id) {
                return Err(GeomapError::Artifact(format!(
                    "identity base is missing id {id}, which is not a \
                     pending append either"
                )));
            }
        }
    }

    let delta = DeltaSegment {
        k,
        factors: d_factors,
        ids: d_ids,
        alive,
        postings: d_postings,
        row_of: d_row_of,
        nnz,
    };
    // an identity base keeps its id maps implicit in memory (the
    // serialised copies were only needed for validation above)
    let (ids, row_of) =
        if identity { (Vec::new(), Vec::new()) } else { (ids, row_of) };
    Ok(GeomapEngine {
        mapper: Arc::new(mapper),
        base: Arc::new(BaseSegment { index, items, ids, row_of, identity }),
        base_dead,
        dead_rows,
        delta,
        live,
        addr,
        min_overlap: spec.min_overlap,
        mutation: spec.mutation,
        postings: spec.postings,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrips_every_field() {
        let spec = Engine::builder()
            .backend(Backend::Superbit { bits: 5, depth: 2, tables: 3 })
            .schema(SchemaConfig::DaryOneHot { d: 4 })
            .threshold(1.25)
            .min_overlap(2)
            .seed(u64::MAX - 7)
            .mutation(MutationConfig { max_delta: 77 })
            .quant(QuantMode::Int8 { refine: 6 });
        let j = spec_to_json(&spec);
        let text = j.to_string_compact();
        let back = spec_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.same_spec(&spec));
        let spec = Engine::builder().postings(PostingsMode::Packed);
        let back = spec_from_json(
            &Json::parse(&spec_to_json(&spec).to_string_compact()).unwrap(),
        )
        .unwrap();
        assert!(back.same_spec(&spec));
    }

    #[test]
    fn v1_spec_without_quant_keys_defaults_off() {
        // a pre-quantization snapshot config parses to the old defaults
        let j = Json::parse(
            r#"{"backend": "geomap", "schema": "ternary-parsetree",
                "threshold": 0.5, "min_overlap": 1, "seed": "7",
                "max_delta": 8}"#,
        )
        .unwrap();
        let spec = spec_from_json(&j).unwrap();
        assert!(spec.same_spec(
            &Engine::builder()
                .schema(SchemaConfig::TernaryParseTree)
                .threshold(0.5)
                .min_overlap(1)
                .seed(7)
                .mutation(MutationConfig { max_delta: 8 })
                .quant(QuantMode::Off)
                .postings(PostingsMode::Raw)
        ));
    }

    #[test]
    fn bitmap_roundtrip() {
        for n in [0usize, 1, 7, 8, 9, 64, 65] {
            let bits: Vec<bool> = (0..n).map(|i| i % 3 == 0).collect();
            let mut buf = Vec::new();
            push_bitmap(&mut buf, &bits);
            assert_eq!(buf.len(), n.div_ceil(8));
            assert_eq!(read_bitmap(&buf, n), bits);
        }
    }

    #[test]
    fn malformed_spec_rejected() {
        let j = Json::parse(r#"{"backend": "geomap"}"#).unwrap();
        assert!(spec_from_json(&j).is_err(), "missing keys");
        let j = Json::parse(
            r#"{"backend": "geomap", "schema": "ternary-parsetree",
                "threshold": 0.5, "min_overlap": 1, "seed": "not a number",
                "max_delta": 8}"#,
        )
        .unwrap();
        assert!(spec_from_json(&j).is_err(), "bad seed");
    }
}
