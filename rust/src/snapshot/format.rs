//! The `GSNP` on-disk container (format layer, no engine knowledge).
//!
//! A snapshot file is a self-describing binary container:
//!
//! ```text
//! ┌────────────────────────────────────────────────────────────────┐
//! │ header (64 B): magic "GSNP", version, section count,           │
//! │                table offset, file length, table CRC32          │
//! ├────────────────────────────────────────────────────────────────┤
//! │ payload 0   (64-byte aligned, zero-padded gap before it)       │
//! │ payload 1   ...                                                │
//! ├────────────────────────────────────────────────────────────────┤
//! │ section table (32 B/entry): kind, shard, offset, len, CRC32    │
//! └────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Everything is little-endian. The table sits at the *end* so the
//! [`Writer`] can stream payloads through one reusable buffer in a
//! single pass and patch the fixed-size header afterwards. Payload
//! offsets are 64-byte aligned and the [`Reader`] holds the whole file
//! in an 8-byte-aligned buffer, so `u32`/`f32` arrays are reconstructed
//! by reinterpreting the payload bytes in place — one memcpy per owning
//! vector, no per-element re-parse (see [`cast_u32s`] / [`cast_f32s`]).
//!
//! Versioning policy (docs/SNAPSHOT.md): readers accept exactly the
//! versions they know; an unknown *version* is an error, an unknown
//! *section kind* within a known version is skipped (forward-compatible
//! additions). Each known section kind carries the minimum format
//! version that defines it; a file whose header declares an older
//! version but contains a newer kind is rejected as inconsistent. The
//! [`Writer`] stamps the lowest version that covers the sections it
//! actually wrote, so snapshots without version-2 state (quantized
//! factors, packed postings) stay readable by version-1 readers.

use crate::error::{GeomapError, Result};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write as _};

/// File magic, first four bytes of every snapshot.
pub const MAGIC: [u8; 4] = *b"GSNP";
/// Newest container format version this build writes and reads.
pub const VERSION: u16 = 2;
/// Oldest format version this build still reads.
pub const MIN_VERSION: u16 = 1;
/// Payload alignment in bytes.
pub const ALIGN: usize = 64;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 64;
/// Section-table entry size in bytes.
pub const ENTRY_LEN: usize = 32;
/// Shard ordinal reserved for file-global sections.
pub const GLOBAL_SHARD: u16 = u16::MAX;

/// Section kinds (codes 1–5: format version 1; 6–7: version 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SectionKind {
    /// Engine/build configuration as JSON (round-trips through configx).
    Config,
    /// Dense factor matrix (rows, cols, row-major f32).
    Factors,
    /// CSR inverted index (offsets + postings arenas).
    Index,
    /// Base-segment id mapping + tombstone bitmap.
    BaseMap,
    /// Delta segment (pending upserts) of the mutation state.
    Delta,
    /// Int8 quantized factor tier (scales + codes), format v2.
    Quant,
    /// Bit-packed posting arena of the inverted index, format v2.
    PackedIndex,
}

impl SectionKind {
    /// Wire code.
    pub fn code(self) -> u16 {
        match self {
            SectionKind::Config => 1,
            SectionKind::Factors => 2,
            SectionKind::Index => 3,
            SectionKind::BaseMap => 4,
            SectionKind::Delta => 5,
            SectionKind::Quant => 6,
            SectionKind::PackedIndex => 7,
        }
    }

    /// Decode a wire code (`None` for kinds this build does not know).
    pub fn from_code(code: u16) -> Option<SectionKind> {
        match code {
            1 => Some(SectionKind::Config),
            2 => Some(SectionKind::Factors),
            3 => Some(SectionKind::Index),
            4 => Some(SectionKind::BaseMap),
            5 => Some(SectionKind::Delta),
            6 => Some(SectionKind::Quant),
            7 => Some(SectionKind::PackedIndex),
            _ => None,
        }
    }

    /// The format version that introduced this kind; a writer holding
    /// such a section stamps at least this version, and a reader rejects
    /// a file whose declared version predates a kind it contains.
    pub fn min_version(self) -> u16 {
        match self {
            SectionKind::Config
            | SectionKind::Factors
            | SectionKind::Index
            | SectionKind::BaseMap
            | SectionKind::Delta => 1,
            SectionKind::Quant | SectionKind::PackedIndex => 2,
        }
    }

    /// Human-readable name (inspect output).
    pub fn name(self) -> &'static str {
        match self {
            SectionKind::Config => "config",
            SectionKind::Factors => "factors",
            SectionKind::Index => "index",
            SectionKind::BaseMap => "base-map",
            SectionKind::Delta => "delta",
            SectionKind::Quant => "quant",
            SectionKind::PackedIndex => "packed-index",
        }
    }
}

// ---------------------------------------------------------------- crc32

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE, the zlib/zip polynomial) of `bytes`.
///
/// Shared integrity primitive for the snapshot container *and* the GMF1
/// factor files (`data::io`).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ------------------------------------------------------- aligned buffer

/// A byte buffer whose base address is 8-byte aligned (backed by
/// `Vec<u64>`), so any 64-byte-aligned file offset is at least 8-byte
/// aligned in memory and `u32`/`f32` payloads can be cast in place.
pub struct AlignedBuf {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    /// Read an entire file.
    pub fn read_file(path: &str) -> Result<AlignedBuf> {
        let mut f = File::open(path).map_err(|e| GeomapError::io(path, e))?;
        let len = f
            .metadata()
            .map_err(|e| GeomapError::io(path, e))?
            .len() as usize;
        let mut buf = AlignedBuf { words: vec![0u64; len.div_ceil(8)], len };
        f.read_exact(buf.bytes_mut()).map_err(|e| GeomapError::io(path, e))?;
        Ok(buf)
    }

    /// The file bytes.
    pub fn bytes(&self) -> &[u8] {
        // SAFETY: words owns at least `len` initialised bytes and u8 has
        // no alignment or validity requirements.
        unsafe {
            std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len)
        }
    }

    fn bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: as above, and we hold &mut self.
        unsafe {
            std::slice::from_raw_parts_mut(
                self.words.as_mut_ptr() as *mut u8,
                self.len,
            )
        }
    }
}

// ------------------------------------------------------- cast helpers

/// Reinterpret a little-endian byte payload as `u32`s: a single memcpy
/// when the slice is 4-byte aligned on a little-endian host, an explicit
/// per-element decode otherwise.
pub fn cast_u32s(bytes: &[u8]) -> Result<Vec<u32>> {
    if bytes.len() % 4 != 0 {
        return Err(GeomapError::Artifact(format!(
            "u32 payload length {} is not a multiple of 4",
            bytes.len()
        )));
    }
    #[cfg(target_endian = "little")]
    {
        // SAFETY: any bit pattern is a valid u32.
        let (pre, mid, post) = unsafe { bytes.align_to::<u32>() };
        if pre.is_empty() && post.is_empty() {
            return Ok(mid.to_vec());
        }
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// [`cast_u32s`] for `f32` payloads.
pub fn cast_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        return Err(GeomapError::Artifact(format!(
            "f32 payload length {} is not a multiple of 4",
            bytes.len()
        )));
    }
    #[cfg(target_endian = "little")]
    {
        // SAFETY: any bit pattern is a valid f32 (NaNs included).
        let (pre, mid, post) = unsafe { bytes.align_to::<f32>() };
        if pre.is_empty() && post.is_empty() {
            return Ok(mid.to_vec());
        }
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Append `xs` to `buf` as little-endian bytes (one memcpy on LE hosts).
pub fn push_u32s(buf: &mut Vec<u8>, xs: &[u32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: reading a u32 slice as bytes is always valid.
        let raw = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
        };
        buf.extend_from_slice(raw);
        return;
    }
    #[cfg(not(target_endian = "little"))]
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// [`push_u32s`] for `f32` values.
pub fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: reading an f32 slice as bytes is always valid.
        let raw = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
        };
        buf.extend_from_slice(raw);
        return;
    }
    #[cfg(not(target_endian = "little"))]
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

// ------------------------------------------------------------- cursor

/// Bounds-checked sequential decoder over one section payload; every
/// short read is a clear `Artifact` error instead of a panic.
pub struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> Cursor<'a> {
    /// Decode `bytes` of a section named `what` (for error messages).
    pub fn new(bytes: &'a [u8], what: &'static str) -> Self {
        Cursor { bytes, pos: 0, what }
    }

    /// Take the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.bytes.len() - self.pos < n {
            return Err(GeomapError::Artifact(format!(
                "{} section truncated: need {n} bytes at offset {} of {}",
                self.what,
                self.pos,
                self.bytes.len()
            )));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Next `u64` (LE).
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Next `u64` that must fit a `usize` count.
    pub fn count(&mut self, what: &str) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).ok().filter(|&n| n <= (1usize << 40)).ok_or_else(
            || {
                GeomapError::Artifact(format!(
                    "{}: implausible {what} count {v}",
                    self.what
                ))
            },
        )
    }

    /// Next `u8`.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// All remaining bytes.
    pub fn rest(mut self) -> &'a [u8] {
        let s = &self.bytes[self.pos..];
        self.pos = self.bytes.len();
        s
    }

    /// Assert the payload was fully consumed.
    pub fn done(&self) -> Result<()> {
        if self.pos != self.bytes.len() {
            return Err(GeomapError::Artifact(format!(
                "{} section has {} trailing bytes",
                self.what,
                self.bytes.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ------------------------------------------------------------- writer

/// One section-table entry.
#[derive(Clone, Debug)]
pub struct SectionEntry {
    /// Raw wire code (kept raw so unknown kinds survive inspect).
    pub kind: u16,
    /// Owning shard ordinal, or [`GLOBAL_SHARD`].
    pub shard: u16,
    /// Payload offset from file start (64-byte aligned).
    pub offset: u64,
    /// Payload length in bytes (unpadded).
    pub len: u64,
    /// CRC-32 of the payload bytes.
    pub crc: u32,
}

/// Streaming snapshot writer: payloads pass through one reusable buffer
/// and hit the file once; the section table and header are written at
/// [`finish`](Writer::finish).
pub struct Writer {
    file: File,
    path: String,
    buf: Vec<u8>,
    entries: Vec<SectionEntry>,
    pos: u64,
    /// Lowest format version covering every section written so far.
    version: u16,
}

impl Writer {
    /// Create (truncate) `path` and reserve the header.
    pub fn create(path: &str) -> Result<Writer> {
        let mut file = File::create(path).map_err(|e| GeomapError::io(path, e))?;
        file.write_all(&[0u8; HEADER_LEN])
            .map_err(|e| GeomapError::io(path, e))?;
        Ok(Writer {
            file,
            path: path.to_string(),
            buf: Vec::new(),
            entries: Vec::new(),
            pos: HEADER_LEN as u64,
            version: MIN_VERSION,
        })
    }

    /// Start a section: returns the cleared reusable payload buffer.
    pub fn begin(&mut self) -> &mut Vec<u8> {
        self.buf.clear();
        &mut self.buf
    }

    /// The format version the header will stamp, given the sections
    /// committed so far (the lowest version covering all of them).
    pub fn version(&self) -> u16 {
        self.version
    }

    /// Commit the buffered payload as a `(kind, shard)` section.
    pub fn end(&mut self, kind: SectionKind, shard: u16) -> Result<()> {
        self.version = self.version.max(kind.min_version());
        let offset = self.pad_to_align()?;
        let path = &self.path;
        self.file
            .write_all(&self.buf)
            .map_err(|e| GeomapError::io(path, e))?;
        self.entries.push(SectionEntry {
            kind: kind.code(),
            shard,
            offset,
            len: self.buf.len() as u64,
            crc: crc32(&self.buf),
        });
        self.pos = offset + self.buf.len() as u64;
        Ok(())
    }

    fn pad_to_align(&mut self) -> Result<u64> {
        let rem = (self.pos % ALIGN as u64) as usize;
        if rem != 0 {
            let zeros = [0u8; ALIGN];
            let path = &self.path;
            self.file
                .write_all(&zeros[..ALIGN - rem])
                .map_err(|e| GeomapError::io(path, e))?;
            self.pos += (ALIGN - rem) as u64;
        }
        Ok(self.pos)
    }

    /// Write the section table, patch the header, sync. Returns the
    /// final file length in bytes.
    pub fn finish(mut self) -> Result<u64> {
        let table_offset = self.pad_to_align()?;
        let mut table = Vec::with_capacity(self.entries.len() * ENTRY_LEN);
        for e in &self.entries {
            table.extend_from_slice(&e.kind.to_le_bytes());
            table.extend_from_slice(&e.shard.to_le_bytes());
            table.extend_from_slice(&0u32.to_le_bytes());
            table.extend_from_slice(&e.offset.to_le_bytes());
            table.extend_from_slice(&e.len.to_le_bytes());
            table.extend_from_slice(&e.crc.to_le_bytes());
            table.extend_from_slice(&0u32.to_le_bytes());
        }
        let file_len = table_offset + table.len() as u64;

        let mut header = [0u8; HEADER_LEN];
        header[0..4].copy_from_slice(&MAGIC);
        header[4..6].copy_from_slice(&self.version.to_le_bytes());
        header[6..8].copy_from_slice(&0u16.to_le_bytes()); // flags
        header[8..12].copy_from_slice(&(self.entries.len() as u32).to_le_bytes());
        header[12..20].copy_from_slice(&table_offset.to_le_bytes());
        header[20..28].copy_from_slice(&file_len.to_le_bytes());
        header[28..32].copy_from_slice(&crc32(&table).to_le_bytes());

        let Writer { mut file, path, .. } = self;
        file.write_all(&table).map_err(|e| GeomapError::io(path.as_str(), e))?;
        file.seek(SeekFrom::Start(0)).map_err(|e| GeomapError::io(path.as_str(), e))?;
        file.write_all(&header).map_err(|e| GeomapError::io(path.as_str(), e))?;
        file.sync_all().map_err(|e| GeomapError::io(path.as_str(), e))?;
        Ok(file_len)
    }
}

// ------------------------------------------------------------- reader

/// Parsed snapshot: the whole file plus its validated section table.
pub struct Reader {
    buf: AlignedBuf,
    entries: Vec<SectionEntry>,
    version: u16,
    /// Per-entry payload CRC status (filled by [`Reader::open`]).
    crc_ok: Vec<bool>,
}

impl Reader {
    /// Open and fully validate: header, table CRC, per-section bounds
    /// and payload CRCs. Any mismatch is an error.
    pub fn open(path: &str) -> Result<Reader> {
        let r = Self::open_tolerant(path)?;
        for (i, ok) in r.crc_ok.iter().enumerate() {
            if !ok {
                let e = &r.entries[i];
                return Err(GeomapError::Artifact(format!(
                    "{path}: section {}/{} payload CRC mismatch (corrupt \
                     snapshot)",
                    section_name(e.kind),
                    e.shard
                )));
            }
        }
        Ok(r)
    }

    /// Open validating the header and table, but record (rather than
    /// reject) payload CRC mismatches — the `inspect` path.
    pub fn open_tolerant(path: &str) -> Result<Reader> {
        let buf = AlignedBuf::read_file(path)?;
        let bytes = buf.bytes();
        if bytes.len() < HEADER_LEN {
            return Err(GeomapError::Artifact(format!(
                "{path}: {} bytes is too short for a GSNP snapshot",
                bytes.len()
            )));
        }
        if bytes[0..4] != MAGIC {
            return Err(GeomapError::Artifact(format!(
                "{path}: not a GSNP snapshot (bad magic)"
            )));
        }
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(GeomapError::Artifact(format!(
                "{path}: unsupported snapshot version {version} (this build \
                 reads versions {MIN_VERSION}..={VERSION})"
            )));
        }
        let count =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let table_offset =
            u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let file_len = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
        let table_crc = u32::from_le_bytes(bytes[28..32].try_into().unwrap());
        if file_len != bytes.len() as u64 {
            return Err(GeomapError::Artifact(format!(
                "{path}: truncated snapshot (header says {file_len} bytes, \
                 file has {})",
                bytes.len()
            )));
        }
        let table_len = count
            .checked_mul(ENTRY_LEN)
            .filter(|&l| {
                table_offset >= HEADER_LEN
                    && table_offset.checked_add(l).is_some_and(|end| {
                        end as u64 <= file_len
                    })
            })
            .ok_or_else(|| {
                GeomapError::Artifact(format!(
                    "{path}: section table out of bounds"
                ))
            })?;
        let table = &bytes[table_offset..table_offset + table_len];
        if crc32(table) != table_crc {
            return Err(GeomapError::Artifact(format!(
                "{path}: section table CRC mismatch (corrupt snapshot)"
            )));
        }
        let mut entries = Vec::with_capacity(count);
        for chunk in table.chunks_exact(ENTRY_LEN) {
            let e = SectionEntry {
                kind: u16::from_le_bytes(chunk[0..2].try_into().unwrap()),
                shard: u16::from_le_bytes(chunk[2..4].try_into().unwrap()),
                offset: u64::from_le_bytes(chunk[8..16].try_into().unwrap()),
                len: u64::from_le_bytes(chunk[16..24].try_into().unwrap()),
                crc: u32::from_le_bytes(chunk[24..28].try_into().unwrap()),
            };
            if e.offset % ALIGN as u64 != 0
                || e.offset.checked_add(e.len).map_or(true, |end| end > file_len)
            {
                return Err(GeomapError::Artifact(format!(
                    "{path}: section {}/{} payload out of bounds",
                    section_name(e.kind),
                    e.shard
                )));
            }
            // a section kind newer than the declared format version is a
            // mutilated or forged header, not a forward-compat skip
            if let Some(kind) = SectionKind::from_code(e.kind) {
                if kind.min_version() > version {
                    return Err(GeomapError::Artifact(format!(
                        "{path}: section '{}' requires format version {} \
                         but the header declares version {version}",
                        kind.name(),
                        kind.min_version()
                    )));
                }
            }
            entries.push(e);
        }
        let crc_ok = entries
            .iter()
            .map(|e| {
                let lo = e.offset as usize;
                crc32(&bytes[lo..lo + e.len as usize]) == e.crc
            })
            .collect();
        Ok(Reader { buf, entries, version, crc_ok })
    }

    /// Container version.
    pub fn version(&self) -> u16 {
        self.version
    }

    /// All table entries, file order.
    pub fn entries(&self) -> &[SectionEntry] {
        &self.entries
    }

    /// Payload CRC status parallel to [`entries`](Reader::entries).
    pub fn crc_status(&self) -> &[bool] {
        &self.crc_ok
    }

    /// Payload of the `(kind, shard)` section, if present.
    pub fn opt_section(&self, kind: SectionKind, shard: u16) -> Option<&[u8]> {
        let e = self
            .entries
            .iter()
            .find(|e| e.kind == kind.code() && e.shard == shard)?;
        let lo = e.offset as usize;
        Some(&self.buf.bytes()[lo..lo + e.len as usize])
    }

    /// Payload of a required `(kind, shard)` section.
    pub fn section(&self, kind: SectionKind, shard: u16) -> Result<&[u8]> {
        self.opt_section(kind, shard).ok_or_else(|| {
            GeomapError::Artifact(format!(
                "snapshot is missing the {}/{shard} section",
                kind.name()
            ))
        })
    }

    /// Shard ordinals present in the file (sorted, unique, the global
    /// pseudo-shard excluded).
    pub fn shard_ids(&self) -> Vec<u16> {
        let mut ids: Vec<u16> = self
            .entries
            .iter()
            .map(|e| e.shard)
            .filter(|&s| s != GLOBAL_SHARD)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

/// Name of a (possibly unknown) section code.
pub fn section_name(code: u16) -> String {
    match SectionKind::from_code(code) {
        Some(k) => k.name().to_string(),
        None => format!("unknown({code})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("geomap-snapshot-format");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn crc32_known_vectors() {
        // standard test vector for CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn container_roundtrip() {
        let path = tmp("roundtrip.gsnp");
        let mut w = Writer::create(&path).unwrap();
        w.begin().extend_from_slice(b"{\"a\":1}");
        w.end(SectionKind::Config, GLOBAL_SHARD).unwrap();
        let buf = w.begin();
        push_u32s(buf, &[1, 2, 3, 500_000]);
        w.end(SectionKind::Index, 0).unwrap();
        let buf = w.begin();
        push_f32s(buf, &[0.5, -1.25]);
        w.end(SectionKind::Factors, 0).unwrap();
        let len = w.finish().unwrap();
        assert_eq!(len, std::fs::metadata(&path).unwrap().len());

        let r = Reader::open(&path).unwrap();
        // no v2 sections were written, so the file stamps version 1
        assert_eq!(r.version(), MIN_VERSION);
        assert_eq!(r.entries().len(), 3);
        assert_eq!(
            r.section(SectionKind::Config, GLOBAL_SHARD).unwrap(),
            b"{\"a\":1}"
        );
        let idx = r.section(SectionKind::Index, 0).unwrap();
        assert_eq!(cast_u32s(idx).unwrap(), vec![1, 2, 3, 500_000]);
        let f = r.section(SectionKind::Factors, 0).unwrap();
        assert_eq!(cast_f32s(f).unwrap(), vec![0.5, -1.25]);
        assert_eq!(r.shard_ids(), vec![0]);
        // payloads are aligned
        for e in r.entries() {
            assert_eq!(e.offset % ALIGN as u64, 0);
        }
        assert!(r.opt_section(SectionKind::Delta, 0).is_none());
        assert!(r.section(SectionKind::Delta, 0).is_err());
    }

    #[test]
    fn corrupt_payload_rejected_but_inspectable() {
        let path = tmp("corrupt.gsnp");
        let mut w = Writer::create(&path).unwrap();
        w.begin().extend_from_slice(b"payload payload payload");
        w.end(SectionKind::Config, GLOBAL_SHARD).unwrap();
        w.finish().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[HEADER_LEN + 3] ^= 0xFF; // flip a payload byte
        std::fs::write(&path, &bytes).unwrap();
        assert!(Reader::open(&path).is_err());
        let r = Reader::open_tolerant(&path).unwrap();
        assert_eq!(r.crc_status(), &[false]);
    }

    #[test]
    fn truncation_and_bad_magic_rejected() {
        let path = tmp("trunc.gsnp");
        let mut w = Writer::create(&path).unwrap();
        w.begin().extend_from_slice(&[7u8; 100]);
        w.end(SectionKind::Factors, 0).unwrap();
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(Reader::open(&path).is_err());

        let magic = tmp("magic.gsnp");
        std::fs::write(&magic, b"not a snapshot at all........................")
            .unwrap();
        let err = Reader::open(&magic).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn future_version_rejected() {
        let path = tmp("version.gsnp");
        let mut w = Writer::create(&path).unwrap();
        w.begin().extend_from_slice(b"x");
        w.end(SectionKind::Config, GLOBAL_SHARD).unwrap();
        w.finish().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4] = 99; // version low byte
        std::fs::write(&path, &bytes).unwrap();
        let err = Reader::open(&path).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
    }

    #[test]
    fn writer_stamps_minimum_covering_version() {
        // v2 sections raise the stamped version; their absence keeps the
        // file readable by version-1 readers
        let path = tmp("v2.gsnp");
        let mut w = Writer::create(&path).unwrap();
        w.begin().extend_from_slice(b"{}");
        w.end(SectionKind::Config, GLOBAL_SHARD).unwrap();
        let buf = w.begin();
        push_f32s(buf, &[1.0]);
        buf.push(0);
        w.end(SectionKind::Quant, 0).unwrap();
        w.finish().unwrap();
        let r = Reader::open(&path).unwrap();
        assert_eq!(r.version(), 2);
        assert_eq!(
            SectionKind::from_code(r.entries()[1].kind),
            Some(SectionKind::Quant)
        );
    }

    #[test]
    fn v1_header_with_v2_section_rejected() {
        // an old reader must never half-read quantized state; symmetric
        // here: a v1-declared file *containing* a v2 kind is inconsistent
        let path = tmp("forged-v1.gsnp");
        let mut w = Writer::create(&path).unwrap();
        w.begin().extend_from_slice(b"payload");
        w.end(SectionKind::PackedIndex, 0).unwrap();
        w.finish().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes[4], 2, "packed-index must have stamped v2");
        bytes[4] = 1; // forge the header back to version 1
        std::fs::write(&path, &bytes).unwrap();
        let err = Reader::open(&path).unwrap_err().to_string();
        assert!(
            err.contains("packed-index") && err.contains("version"),
            "{err}"
        );
    }

    #[test]
    fn cursor_reads_and_reports_truncation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&7u64.to_le_bytes());
        buf.push(1);
        buf.extend_from_slice(b"tail");
        let mut c = Cursor::new(&buf, "test");
        assert_eq!(c.u64().unwrap(), 7);
        assert_eq!(c.u8().unwrap(), 1);
        assert_eq!(c.rest(), b"tail");
        let mut c2 = Cursor::new(&buf[..3], "test");
        let err = c2.u64().unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn cast_rejects_ragged_lengths() {
        assert!(cast_u32s(&[1, 2, 3]).is_err());
        assert!(cast_f32s(&[1, 2, 3, 4, 5]).is_err());
        assert_eq!(cast_u32s(&[]).unwrap(), Vec::<u32>::new());
    }
}
