//! Snapshot subsystem: versioned zero-copy persistence and warm-start
//! for built engines (`docs/SNAPSHOT.md`).
//!
//! The paper's economics put all the expensive work offline —
//! tessellating the sphere, building the permutation maps, materialising
//! the inverted index — so serving stays cheap. Before this subsystem
//! only raw factor matrices persisted (`GMF1`), and every process start
//! re-paid the entire build. A snapshot persists the *built* engine
//! state instead, so a coordinator cold-starts by reinterpreting aligned
//! bytes rather than re-mapping the catalogue:
//!
//! * [`format`] — the `GSNP` container: versioned header, CRC32-guarded
//!   section table, 64-byte-aligned little-endian payloads.
//! * [`save_engine`] / [`load_engine`] — single-engine persistence
//!   (`Engine::save_snapshot` / `EngineBuilder::from_snapshot` are the
//!   ergonomic entry points).
//! * [`save_engines`] / [`load_engines`] — multi-shard persistence used
//!   by the coordinator's `FactorStore` for checkpoints and warm starts.
//! * [`checkpoint`] — the background checkpointer: atomic tmp+rename
//!   writes, keep-last-N retention, final checkpoint on shutdown.
//! * [`inspect`] — header/section/config report without reconstruction.

pub mod checkpoint;
mod codec;
pub mod format;

pub use checkpoint::{latest_snapshot, Checkpointer};
pub use format::crc32;

use crate::configx::{obj, Json};
use crate::engine::Engine;
use crate::error::{GeomapError, Result};
use format::{Reader, SectionKind, Writer, GLOBAL_SHARD};

/// A loaded multi-shard snapshot.
pub struct LoadedSnapshot {
    /// Catalogue version at save time (restored by the factor store).
    pub catalogue_version: u64,
    /// `(base_id, engine)` per shard, shard order.
    pub shards: Vec<(u32, Engine)>,
}

/// Persist a sharded engine set to `path`, atomically (the file is
/// written as `<path>.tmp` and renamed into place). `shards` pairs each
/// engine with the global item id of its local id 0. Returns the file
/// size in bytes.
pub fn save_engines(
    path: &str,
    shards: &[(u32, &Engine)],
    catalogue_version: u64,
) -> Result<u64> {
    if shards.is_empty() {
        return Err(GeomapError::Config(
            "cannot snapshot an empty shard set".into(),
        ));
    }
    if shards.len() >= GLOBAL_SHARD as usize {
        return Err(GeomapError::Config(format!(
            "{} shards exceed the snapshot shard limit",
            shards.len()
        )));
    }
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| GeomapError::io(path, e))?;
        }
    }
    let tmp = format!("{path}.tmp");
    let mut w = Writer::create(&tmp)?;
    let total_items: usize = shards.iter().map(|(_, e)| e.len()).sum();
    for (ordinal, &(_, engine)) in shards.iter().enumerate() {
        codec::write_engine(&mut w, ordinal as u16, engine)?;
    }
    // the global config goes last so its "format" field can record the
    // version the header will actually stamp (v2 only when an engine
    // contributed compressed sections); readers look sections up by
    // kind + shard, so order is free
    let global = obj(vec![
        ("format", Json::from(w.version() as usize)),
        ("shards", Json::from(shards.len())),
        ("total_items", Json::from(total_items)),
        ("version", Json::from(catalogue_version.to_string())),
        (
            "base_ids",
            Json::from(
                shards.iter().map(|&(b, _)| b as usize).collect::<Vec<_>>(),
            ),
        ),
    ]);
    w.begin().extend_from_slice(global.to_string_compact().as_bytes());
    w.end(SectionKind::Config, GLOBAL_SHARD)?;
    let bytes = w.finish()?;
    std::fs::rename(&tmp, path).map_err(|e| GeomapError::io(path, e))?;
    Ok(bytes)
}

/// Persist one engine (shard 0, base id 0) to `path`.
pub fn save_engine(path: &str, engine: &Engine) -> Result<u64> {
    save_engines(path, &[(0, engine)], 0)
}

fn read_global(r: &Reader) -> Result<(usize, u64, Vec<u32>)> {
    let bytes = r.section(SectionKind::Config, GLOBAL_SHARD)?;
    let text = std::str::from_utf8(bytes).map_err(|_| {
        GeomapError::Artifact("snapshot global config is not UTF-8".into())
    })?;
    let j = Json::parse(text)?;
    let shards = j.get("shards")?.as_usize()?;
    let version: u64 =
        j.get("version")?.as_str()?.parse().map_err(|_| {
            GeomapError::Artifact(
                "snapshot global config has a malformed version".into(),
            )
        })?;
    let base_ids: Vec<u32> = j
        .get("base_ids")?
        .as_usize_vec()?
        .into_iter()
        .map(|b| b as u32)
        .collect();
    if base_ids.len() != shards {
        return Err(GeomapError::Artifact(format!(
            "snapshot lists {shards} shards but {} base ids",
            base_ids.len()
        )));
    }
    Ok((shards, version, base_ids))
}

/// Load every shard engine from `path`, fully verifying section CRCs
/// and cross-validating the reconstructed state.
pub fn load_engines(path: &str) -> Result<LoadedSnapshot> {
    let r = Reader::open(path)?;
    let (n_shards, catalogue_version, base_ids) = read_global(&r)?;
    if n_shards == 0 {
        return Err(GeomapError::Artifact(format!(
            "{path}: snapshot declares zero shards"
        )));
    }
    let present = r.shard_ids();
    if present.len() != n_shards
        || present.iter().enumerate().any(|(i, &s)| s as usize != i)
    {
        return Err(GeomapError::Artifact(format!(
            "{path}: snapshot announces {n_shards} shards but holds \
             sections for {:?}",
            present
        )));
    }
    let mut shards = Vec::with_capacity(n_shards);
    for (ordinal, &base_id) in base_ids.iter().enumerate() {
        let engine = codec::read_engine(&r, ordinal as u16)?;
        shards.push((base_id, engine));
    }
    Ok(LoadedSnapshot { catalogue_version, shards })
}

/// Load a single-engine snapshot (the `Engine::save_snapshot` shape).
pub fn load_engine(path: &str) -> Result<Engine> {
    let mut loaded = load_engines(path)?;
    if loaded.shards.len() != 1 {
        return Err(GeomapError::Config(format!(
            "{path} holds a {}-shard coordinator snapshot; warm-start it \
             through Coordinator::start_from_snapshot",
            loaded.shards.len()
        )));
    }
    Ok(loaded.shards.pop().unwrap().1)
}

/// One section row of an [`inspect`] report.
#[derive(Clone, Debug)]
pub struct SectionInfo {
    /// Section kind name (unknown codes render as `unknown(n)`).
    pub kind: String,
    /// Owning shard ordinal; `None` for file-global sections.
    pub shard: Option<u16>,
    /// Payload offset in the file.
    pub offset: u64,
    /// Payload length in bytes.
    pub len: u64,
    /// Whether the payload matches its recorded CRC32.
    pub crc_ok: bool,
}

/// One compressed section's size against its uncompressed equivalent.
#[derive(Clone, Debug)]
pub struct CompressionInfo {
    /// Section kind name (`quant`, `packed-index`).
    pub kind: String,
    /// Owning shard ordinal.
    pub shard: u16,
    /// Bytes the same state would occupy uncompressed (f32 factors for
    /// `quant`, raw u32 CSR arenas for `packed-index`).
    pub logical: u64,
    /// Actual payload bytes in the file.
    pub stored: u64,
}

/// Header + section + config report of a snapshot file.
#[derive(Clone, Debug)]
pub struct SnapshotInfo {
    /// Container format version.
    pub format_version: u16,
    /// Total file size in bytes.
    pub file_len: u64,
    /// Shard count.
    pub shards: usize,
    /// Catalogue version recorded at save time.
    pub catalogue_version: u64,
    /// Engine build spec of shard 0 (config section JSON).
    pub spec: Json,
    /// All sections, file order.
    pub sections: Vec<SectionInfo>,
    /// Compressed sections vs their uncompressed equivalents (empty
    /// when the snapshot holds no v2 compressed state).
    pub compression: Vec<CompressionInfo>,
}

impl SnapshotInfo {
    /// True when every payload CRC verified.
    pub fn intact(&self) -> bool {
        self.sections.iter().all(|s| s.crc_ok)
    }

    /// Multi-line human-readable report (CLI `snapshot inspect`).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "GSNP v{}  {} bytes  {} shard(s)  catalogue version {}  {}",
            self.format_version,
            self.file_len,
            self.shards,
            self.catalogue_version,
            if self.intact() { "intact" } else { "CORRUPT" },
        );
        let _ = writeln!(s, "spec: {}", self.spec.to_string_compact());
        if !self.compression.is_empty() {
            let (logical, stored) = self
                .compression
                .iter()
                .fold((0u64, 0u64), |(l, t), c| (l + c.logical, t + c.stored));
            let per: Vec<String> = self
                .compression
                .iter()
                .map(|c| {
                    format!(
                        "{}/{} {} → {} B ({:.1}x)",
                        c.kind,
                        c.shard,
                        c.logical,
                        c.stored,
                        c.logical as f64 / (c.stored as f64).max(1.0)
                    )
                })
                .collect();
            let _ = writeln!(
                s,
                "compression: {:.1}x overall ({})",
                logical as f64 / (stored as f64).max(1.0),
                per.join(", ")
            );
        }
        let _ = writeln!(
            s,
            "{:<12} {:>6} {:>12} {:>12}  crc",
            "section", "shard", "offset", "bytes"
        );
        for sec in &self.sections {
            let shard = match sec.shard {
                Some(x) => x.to_string(),
                None => "-".to_string(),
            };
            let _ = writeln!(
                s,
                "{:<12} {:>6} {:>12} {:>12}  {}",
                sec.kind,
                shard,
                sec.offset,
                sec.len,
                if sec.crc_ok { "ok" } else { "MISMATCH" }
            );
        }
        s
    }
}

/// Report a snapshot's header, sections and config without rebuilding
/// any engine. Payload CRC mismatches are *reported*, not fatal, so a
/// damaged file can still be diagnosed.
pub fn inspect(path: &str) -> Result<SnapshotInfo> {
    let r = Reader::open_tolerant(path)?;
    // a corrupt global config must not kill the report — the per-section
    // CRC column is exactly what diagnoses it
    let (shards, catalogue_version) = match read_global(&r) {
        Ok((shards, version, _)) => (shards, version),
        Err(_) => (0, 0),
    };
    let spec = match r.opt_section(SectionKind::Config, 0) {
        Some(bytes) => std::str::from_utf8(bytes)
            .ok()
            .and_then(|t| Json::parse(t).ok())
            .unwrap_or(Json::Null),
        None => Json::Null,
    };
    let sections: Vec<SectionInfo> = r
        .entries()
        .iter()
        .zip(r.crc_status())
        .map(|(e, &ok)| SectionInfo {
            kind: format::section_name(e.kind),
            shard: (e.shard != GLOBAL_SHARD).then_some(e.shard),
            offset: e.offset,
            len: e.len,
            crc_ok: ok,
        })
        .collect();
    // compression report: peek the fixed headers of the v2 compressed
    // sections to recover what the same state would cost uncompressed
    let mut compression = Vec::new();
    for (e, &ok) in r.entries().iter().zip(r.crc_status()) {
        if !ok {
            continue; // a corrupt payload has no trustworthy header
        }
        let kind = match SectionKind::from_code(e.kind) {
            Some(k @ (SectionKind::Quant | SectionKind::PackedIndex)) => k,
            _ => continue,
        };
        let Some(payload) = r.opt_section(kind, e.shard) else {
            continue;
        };
        let word = |i: usize| -> Option<u64> {
            payload
                .get(i * 8..i * 8 + 8)
                .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
        };
        let logical = match kind {
            // n items × k dims of f32
            SectionKind::Quant => match (word(0), word(1)) {
                (Some(n), Some(k)) => {
                    n.checked_mul(k).and_then(|c| c.checked_mul(4))
                }
                _ => None,
            },
            // raw CSR equivalent: postings + (p + 1) offsets, u32 each
            SectionKind::PackedIndex => match (word(1), word(2)) {
                (Some(p), Some(total)) => p
                    .checked_add(1)
                    .and_then(|x| x.checked_add(total))
                    .and_then(|x| x.checked_mul(4)),
                _ => None,
            },
            _ => unreachable!(),
        };
        if let Some(logical) = logical {
            compression.push(CompressionInfo {
                kind: kind.name().to_string(),
                shard: e.shard,
                logical,
                stored: e.len,
            });
        }
    }
    let file_len = std::fs::metadata(path)
        .map(|m| m.len())
        .map_err(|e| GeomapError::io(path, e))?;
    Ok(SnapshotInfo {
        format_version: r.version(),
        file_len,
        shards,
        catalogue_version,
        spec,
        sections,
        compression,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{Backend, MutationConfig, SchemaConfig};
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("geomap-snapshot-mod");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_string_lossy().into_owned()
    }

    fn items(n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seeded(seed);
        Matrix::gaussian(&mut rng, n, k, 1.0)
    }

    #[test]
    fn engine_save_load_inspect() {
        let path = tmp("engine.gsnp");
        let engine = Engine::builder()
            .schema(SchemaConfig::TernaryParseTree)
            .threshold(0.5)
            .mutation(MutationConfig { max_delta: 16 })
            .build(items(120, 8, 1))
            .unwrap();
        let bytes = save_engine(&path, &engine).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());

        let loaded = load_engine(&path).unwrap();
        assert_eq!(loaded.len(), engine.len());
        assert_eq!(loaded.dim(), engine.dim());
        assert_eq!(loaded.label(), engine.label());
        assert!(loaded.spec().same_spec(&engine.spec()));

        let info = inspect(&path).unwrap();
        assert!(info.intact());
        assert_eq!(info.shards, 1);
        assert_eq!(info.catalogue_version, 0);
        assert_eq!(
            info.spec.get("backend").unwrap().as_str().unwrap(),
            "geomap"
        );
        let kinds: Vec<&str> =
            info.sections.iter().map(|s| s.kind.as_str()).collect();
        for want in ["config", "factors", "index", "base-map", "delta"] {
            assert!(kinds.contains(&want), "missing {want} in {kinds:?}");
        }
        assert!(info.render().contains("intact"));
    }

    #[test]
    fn quantized_snapshot_inspects_as_v2_with_compression() {
        use crate::configx::{PostingsMode, QuantMode};
        let path = tmp("quantized.gsnp");
        let engine = Engine::builder()
            .schema(SchemaConfig::TernaryOneHot)
            .threshold(0.5)
            .quant(QuantMode::Int8 { refine: 4 })
            .postings(PostingsMode::Packed)
            .build(items(150, 8, 9))
            .unwrap();
        save_engine(&path, &engine).unwrap();
        let info = inspect(&path).unwrap();
        assert!(info.intact());
        assert_eq!(info.format_version, 2);
        let kinds: Vec<&str> =
            info.sections.iter().map(|s| s.kind.as_str()).collect();
        assert!(kinds.contains(&"quant"), "{kinds:?}");
        assert!(kinds.contains(&"packed-index"), "{kinds:?}");
        assert!(!kinds.contains(&"index"), "raw arena must not be written");
        // the compression report prices the int8 tier against f32
        let quant = info
            .compression
            .iter()
            .find(|c| c.kind == "quant")
            .expect("quant compression entry");
        assert_eq!(quant.logical, 150 * 8 * 4);
        assert!(quant.stored < quant.logical);
        assert!(info.compression.iter().any(|c| c.kind == "packed-index"));
        assert!(info.render().contains("compression:"), "{}", info.render());

        // an unquantized engine keeps the v1 format and no report
        let plain_path = tmp("plain.gsnp");
        let plain = Engine::builder().build(items(50, 8, 10)).unwrap();
        save_engine(&plain_path, &plain).unwrap();
        let info = inspect(&plain_path).unwrap();
        assert_eq!(info.format_version, 1);
        assert!(info.compression.is_empty());
        assert!(!info.render().contains("compression:"));

        // a quantized *baseline* engine also stays v1: its load path
        // rebuilds from factors, requantising deterministically, so no
        // quant section is written
        let brute_path = tmp("quant-brute.gsnp");
        let brute = Engine::builder()
            .backend(Backend::Brute)
            .quant(crate::configx::QuantMode::Int8 { refine: 4 })
            .build(items(40, 8, 11))
            .unwrap();
        save_engine(&brute_path, &brute).unwrap();
        let info = inspect(&brute_path).unwrap();
        assert_eq!(info.format_version, 1);
        assert!(info.compression.is_empty());
        let loaded = load_engine(&brute_path).unwrap();
        let q = loaded.quant_store().expect("requantized on load");
        assert_eq!(q.codes(), brute.quant_store().unwrap().codes());
        assert_eq!(q.scales(), brute.quant_store().unwrap().scales());
    }

    #[test]
    fn multi_shard_snapshot_is_not_a_single_engine() {
        let path = tmp("two-shards.gsnp");
        let a = Engine::builder().build(items(30, 4, 2)).unwrap();
        let b = Engine::builder().build(items(20, 4, 3)).unwrap();
        save_engines(&path, &[(0, &a), (30, &b)], 7).unwrap();
        let loaded = load_engines(&path).unwrap();
        assert_eq!(loaded.catalogue_version, 7);
        assert_eq!(loaded.shards.len(), 2);
        assert_eq!(loaded.shards[1].0, 30);
        assert!(load_engine(&path).is_err(), "single-engine loader refuses");
    }

    #[test]
    fn baseline_engine_roundtrips_via_factors() {
        let path = tmp("baseline.gsnp");
        let its = items(60, 6, 4);
        let engine = Engine::builder()
            .backend(Backend::Srp { bits: 3, tables: 2 })
            .seed(99)
            .build(its.clone())
            .unwrap();
        save_engine(&path, &engine).unwrap();
        let loaded = load_engine(&path).unwrap();
        assert_eq!(loaded.backend(), engine.backend());
        // deterministic rebuild: same candidates for the same user
        let mut rng = Rng::seeded(5);
        let u: Vec<f32> = (0..6).map(|_| rng.gaussian_f32()).collect();
        assert_eq!(
            loaded.candidates(&u).unwrap(),
            engine.candidates(&u).unwrap()
        );
        assert_eq!(loaded.dense_factors().unwrap(), &its);
    }

    #[test]
    fn empty_shard_set_rejected() {
        assert!(save_engines(&tmp("none.gsnp"), &[], 0).is_err());
    }

    #[test]
    fn zero_shard_file_rejected_without_panic() {
        // a hand-rolled file whose global config declares zero shards
        // must fail loudly, not index-panic downstream
        let path = tmp("zero-shards.gsnp");
        let mut w = format::Writer::create(&path).unwrap();
        w.begin().extend_from_slice(
            br#"{"format":1,"shards":0,"total_items":0,"version":"0","base_ids":[]}"#,
        );
        w.end(SectionKind::Config, GLOBAL_SHARD).unwrap();
        w.finish().unwrap();
        let err = load_engines(&path).unwrap_err().to_string();
        assert!(err.contains("zero shards"), "{err}");
        assert!(crate::coordinator::FactorStore::from_snapshot(&path).is_err());
    }
}
