//! Sparse-vector substrate.
//!
//! [`SparseVec`] is the output type of the paper's map φ: a p-dimensional
//! vector stored as sorted (index, value) pairs — the "inverted index
//! representation" costs O(k log p) per factor (paper §4.2.2) because only
//! the k non-zeros are kept.

use crate::error::{GeomapError, Result};

/// Sparse vector in `R^p`: sorted unique indices + parallel values.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseVec {
    dim: usize,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl SparseVec {
    /// Build from parallel arrays; sorts by index and validates.
    pub fn new(dim: usize, mut pairs: Vec<(u32, f32)>) -> Result<Self> {
        pairs.sort_unstable_by_key(|&(i, _)| i);
        for w in pairs.windows(2) {
            if w[0].0 == w[1].0 {
                return Err(GeomapError::Shape(format!(
                    "duplicate sparse index {}",
                    w[0].0
                )));
            }
        }
        if let Some(&(last, _)) = pairs.last() {
            if last as usize >= dim {
                return Err(GeomapError::Shape(format!(
                    "index {last} out of bounds for dim {dim}"
                )));
            }
        }
        let (indices, values) = pairs.into_iter().unzip();
        Ok(SparseVec { dim, indices, values })
    }

    /// Build from a dense slice, keeping entries with |x| > `eps`.
    pub fn from_dense(x: &[f32], eps: f32) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in x.iter().enumerate() {
            if v.abs() > eps {
                indices.push(i as u32);
                values.push(v);
            }
        }
        SparseVec { dim: x.len(), indices, values }
    }

    /// Ambient dimensionality p.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of stored non-zeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Sorted non-zero indices (the sparsity pattern / support).
    #[inline]
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Values parallel to [`indices`](Self::indices).
    #[inline]
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Iterate `(index, value)` pairs in index order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.indices.iter().copied().zip(self.values.iter().copied())
    }

    /// Sparse–sparse dot product (merge join over sorted indices).
    pub fn dot(&self, other: &SparseVec) -> f32 {
        let (mut i, mut j) = (0usize, 0usize);
        let mut acc = 0.0f32;
        while i < self.indices.len() && j < other.indices.len() {
            match self.indices[i].cmp(&other.indices[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    acc += self.values[i] * other.values[j];
                    i += 1;
                    j += 1;
                }
            }
        }
        acc
    }

    /// Size of the support intersection with `other`.
    pub fn overlap(&self, other: &SparseVec) -> usize {
        let (mut i, mut j) = (0usize, 0usize);
        let mut n = 0usize;
        while i < self.indices.len() && j < other.indices.len() {
            match self.indices[i].cmp(&other.indices[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }

    /// True iff the two sparsity patterns are disjoint ("conflicting",
    /// paper footnote 1).
    pub fn conflicts_with(&self, other: &SparseVec) -> bool {
        self.overlap(other) == 0
    }

    /// Materialise as a dense vector (tests / debugging only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        for (i, v) in self.iter() {
            out[i as usize] = v;
        }
        out
    }

    /// ℓ2 norm of the stored values.
    pub fn norm(&self) -> f32 {
        self.values.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

/// Compressed sparse row collection of [`SparseVec`]s with a shared
/// ambient dimension — the natural container for φ(Z).
#[derive(Clone, Debug, Default)]
pub struct SparseMatrix {
    dim: usize,
    /// row start offsets, len = rows + 1
    offsets: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f32>,
}

impl SparseMatrix {
    /// Empty collection with ambient dimension `dim`.
    pub fn with_dim(dim: usize) -> Self {
        SparseMatrix { dim, offsets: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Append one row.
    pub fn push(&mut self, row: &SparseVec) -> Result<()> {
        if row.dim() != self.dim {
            return Err(GeomapError::Shape(format!(
                "row dim {} != matrix dim {}",
                row.dim(),
                self.dim
            )));
        }
        self.indices.extend_from_slice(row.indices());
        self.values.extend_from_slice(row.values());
        self.offsets.push(self.indices.len());
        Ok(())
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Ambient dimension p.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Borrow row `r` as (indices, values).
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.offsets[r], self.offsets[r + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Total stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Mean non-zeros per row.
    pub fn mean_nnz(&self) -> f64 {
        if self.rows() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(dim: usize, pairs: &[(u32, f32)]) -> SparseVec {
        SparseVec::new(dim, pairs.to_vec()).unwrap()
    }

    #[test]
    fn new_sorts_and_validates() {
        let v = sv(10, &[(5, 1.0), (2, 2.0)]);
        assert_eq!(v.indices(), &[2, 5]);
        assert_eq!(v.values(), &[2.0, 1.0]);
        assert!(SparseVec::new(10, vec![(3, 1.0), (3, 2.0)]).is_err());
        assert!(SparseVec::new(3, vec![(3, 1.0)]).is_err());
    }

    #[test]
    fn from_dense_thresholds() {
        let v = SparseVec::from_dense(&[0.0, 0.5, -0.001, 2.0], 0.01);
        assert_eq!(v.indices(), &[1, 3]);
        assert_eq!(v.nnz(), 2);
        assert_eq!(v.dim(), 4);
    }

    #[test]
    fn dot_matches_dense() {
        let a = sv(8, &[(0, 1.0), (3, 2.0), (7, -1.0)]);
        let b = sv(8, &[(3, 4.0), (6, 1.0), (7, 2.0)]);
        let dense: f32 = a
            .to_dense()
            .iter()
            .zip(b.to_dense().iter())
            .map(|(x, y)| x * y)
            .sum();
        assert!((a.dot(&b) - dense).abs() < 1e-6);
        assert_eq!(a.dot(&b), 8.0 - 2.0);
    }

    #[test]
    fn overlap_and_conflict_semantics() {
        // paper footnote 1 example: [9,0,8,0,0] vs [0,6,0,7,3]
        let a = SparseVec::from_dense(&[9.0, 0.0, 8.0, 0.0, 0.0], 0.0);
        let b = SparseVec::from_dense(&[0.0, 6.0, 0.0, 7.0, 3.0], 0.0);
        assert_eq!(a.overlap(&b), 0);
        assert!(a.conflicts_with(&b));
        let c = SparseVec::from_dense(&[1.0, 6.0, 0.0, 0.0, 0.0], 0.0);
        assert_eq!(a.overlap(&c), 1);
        assert!(!a.conflicts_with(&c));
    }

    #[test]
    fn sparse_matrix_roundtrip() {
        let mut m = SparseMatrix::with_dim(16);
        let r0 = sv(16, &[(1, 1.0), (4, -2.0)]);
        let r1 = sv(16, &[(0, 3.0)]);
        m.push(&r0).unwrap();
        m.push(&r1).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0), (&[1u32, 4u32][..], &[1.0f32, -2.0f32][..]));
        assert_eq!(m.row(1), (&[0u32][..], &[3.0f32][..]));
        assert!((m.mean_nnz() - 1.5).abs() < 1e-9);
        assert!(m.push(&sv(8, &[(0, 1.0)])).is_err());
    }
}
