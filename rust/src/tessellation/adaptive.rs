//! Cluster-adaptive non-uniform tessellation — the extension named in
//! paper §5: "for factors which are known to have clustered form, a
//! simple extension of our algorithm would involve a non-uniform
//! tessellation scheme with finer granularity near the cluster centres".
//!
//! Realised as the supplement §B.1 *drop-list* construction over the
//! D-ary grid: the schema is the full Γ_D, but factors **far** from every
//! cluster centre are snapped to the ternary sub-grid {−D, 0, +D}ᵏ ⊂ Γ_D
//! (i.e. the intermediate grid vectors are dropped in sparse regions of
//! the sphere). Near a centre the full `O(k/D²)`-resolution assignment
//! applies. Everything stays a deterministic function of `z` (plus the
//! fixed centre set), so the §3.3 no-storage requirement still holds, and
//! because both regimes emit levels on the *same* D-grid the downstream
//! permutation maps compose unchanged.

use super::{DaryTessellation, TernaryTessellation, TessVector, Tessellation};
use crate::geometry::angular_distance;
use crate::linalg::Matrix;

/// Non-uniform tessellation: D-ary near cluster centres, ternary
/// (scaled onto the D-grid) elsewhere.
pub struct ClusterAdaptive {
    centres: Matrix,
    /// Angular radius within which the fine grid applies.
    pub radius: f32,
    fine: DaryTessellation,
    coarse: TernaryTessellation,
    d: u32,
}

impl ClusterAdaptive {
    /// Build for k-dim factors with fine resolution `d` near the given
    /// unit-norm `centres` (angular `radius`).
    pub fn new(k: usize, d: u32, centres: Matrix, radius: f32) -> Self {
        assert_eq!(centres.cols(), k, "centre dim mismatch");
        assert!(centres.rows() >= 1, "need at least one centre");
        assert!(d >= 1 && radius >= 0.0);
        ClusterAdaptive {
            centres,
            radius,
            fine: DaryTessellation::new(k, d),
            coarse: TernaryTessellation::new(k),
            d,
        }
    }

    /// The cluster centres.
    pub fn centres(&self) -> &Matrix {
        &self.centres
    }

    /// True when `z` is within the fine-grid radius of some centre.
    pub fn is_near_centre(&self, z: &[f32]) -> bool {
        self.centres
            .iter_rows()
            .any(|c| angular_distance(c, z) <= self.radius)
    }
}

impl Tessellation for ClusterAdaptive {
    fn k(&self) -> usize {
        self.coarse.k()
    }

    fn d(&self) -> u32 {
        self.d
    }

    fn assign(&self, z: &[f32]) -> TessVector {
        if self.is_near_centre(z) {
            self.fine.assign(z)
        } else {
            // coarse regime: ternary levels lifted onto the D-grid so the
            // permutation maps see one consistent grid.
            let t = self.coarse.assign(z);
            TessVector {
                levels: t.levels.iter().map(|&l| l * self.d as i16).collect(),
                d: self.d,
            }
        }
    }

    fn name(&self) -> &'static str {
        "cluster-adaptive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::spherical_kmeans;
    use crate::data::clustered_factors;
    use crate::geometry::normalize;
    use crate::rng::Rng;
    use crate::testing::prop;

    fn fixture(seed: u64) -> (Matrix, ClusterAdaptive) {
        let mut rng = Rng::seeded(seed);
        let data = clustered_factors(&mut rng, 200, 16, 4, 0.15);
        let km = spherical_kmeans(&data, 4, 15, &mut rng);
        let tess = ClusterAdaptive::new(16, 8, km.centres, 0.4);
        (data, tess)
    }

    #[test]
    fn near_centre_factors_get_fine_levels() {
        let (data, tess) = fixture(1);
        let mut near = 0usize;
        let mut fine = 0usize;
        for row in data.iter_rows() {
            if !tess.is_near_centre(row) {
                continue; // cluster tails may fall outside the radius
            }
            near += 1;
            let t = tess.assign(row);
            assert_eq!(t.d, 8);
            // fine assignment uses intermediate grid levels somewhere
            if t.levels.iter().any(|&l| l != 0 && l.abs() != 8) {
                fine += 1;
            }
        }
        assert!(near * 10 > data.rows() * 9, "most members are near: {near}");
        assert!(fine > near / 2, "fine grid unused: {fine}/{near}");
    }

    #[test]
    fn far_factors_get_ternary_levels_on_the_d_grid() {
        let (_, tess) = fixture(2);
        let mut rng = Rng::seeded(3);
        let mut far = 0usize;
        for _ in 0..100 {
            let mut z: Vec<f32> = (0..16).map(|_| rng.gaussian_f32()).collect();
            normalize(&mut z);
            if tess.is_near_centre(&z) {
                continue;
            }
            far += 1;
            let t = tess.assign(&z);
            assert_eq!(t.d, 8);
            assert!(
                t.levels.iter().all(|&l| l == 0 || l.abs() == 8),
                "coarse regime must stay on the ternary sub-grid: {:?}",
                t.levels
            );
        }
        assert!(far > 20, "random directions should usually be far");
    }

    #[test]
    fn assignment_is_scale_invariant() {
        let (_, tess) = fixture(4);
        prop(50, |g| {
            let z = g.unit_vector(16);
            let s = g.f32_in(0.1, 20.0);
            let zs: Vec<f32> = z.iter().map(|v| v * s).collect();
            assert_eq!(tess.assign(&z).levels, tess.assign(&zs).levels);
        });
    }

    #[test]
    fn composes_with_permutation_maps() {
        // the adaptive tessellation emits a consistent D-grid, so the
        // standard maps accept its output.
        use crate::permutation::{OneHot, ParseTree, PermutationMap};
        let (data, tess) = fixture(5);
        let one_hot = OneHot::new(16, 8);
        let pt = ParseTree::new(16, 8);
        for row in data.iter_rows().take(20) {
            let t = tess.assign(row);
            let m1 = one_hot.index_map(&t);
            let m2 = pt.index_map(&t);
            assert!(crate::permutation::is_injective(&m1));
            assert!(crate::permutation::is_injective(&m2));
        }
    }

    #[test]
    fn radius_zero_is_all_coarse() {
        let mut rng = Rng::seeded(6);
        let data = clustered_factors(&mut rng, 50, 8, 2, 0.2);
        let km = spherical_kmeans(&data, 2, 5, &mut rng);
        let tess = ClusterAdaptive::new(8, 4, km.centres, 0.0);
        let mut z: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        normalize(&mut z);
        let t = tess.assign(&z);
        // almost surely not exactly on a centre → coarse sub-grid
        assert!(t.levels.iter().all(|&l| l == 0 || l.abs() == 4));
    }
}
