//! D-ary directional tessellation — paper §4.1.2, supplement Algorithm 3.
//!
//! The base set is `B_D = {0, ±1/D, ±2/D, …, ±1}`; Γ_D is all non-zero
//! grid vectors, normalised. Exact projection is hard, but rounding each
//! coordinate to the nearest grid level and renormalising (TessVector-D)
//! gives an ε-approximation with `d(a_z, a*_z) ~ O(k/D²)` (Lemma 2) in
//! O(k) time — no sort needed.
//!
//! The rust implementation matches the pallas kernel
//! `python/compile/kernels/tess_dary.py` bit-for-bit on the golden files
//! (see `rust/tests/golden.rs`), which is how L3 and L1 are pinned to the
//! same semantics.

use super::{TessVector, Tessellation};
use crate::geometry::normalize;

/// ε-approximate D-ary tessellation (Algorithm 3).
#[derive(Clone, Debug)]
pub struct DaryTessellation {
    k: usize,
    d: u32,
}

impl DaryTessellation {
    /// Tessellation over the D-ary grid. `d = 1` degenerates to rounding on
    /// the ternary grid (note: *not* identical to Algorithm 2, which is the
    /// exact search; see `approx_vs_exact_gap` test).
    pub fn new(k: usize, d: u32) -> Self {
        assert!(k > 0, "k must be positive");
        assert!(d >= 1, "D must be >= 1");
        DaryTessellation { k, d }
    }
}

impl Tessellation for DaryTessellation {
    fn k(&self) -> usize {
        self.k
    }

    fn d(&self) -> u32 {
        self.d
    }

    fn assign(&self, z: &[f32]) -> TessVector {
        assert_eq!(z.len(), self.k, "factor dim {} != k {}", z.len(), self.k);
        // Alg. 3 assumes z ∈ S^k; normalise a copy so the schema is
        // scale-invariant like the rest of the stack (paper §5).
        let mut zn = z.to_vec();
        let norm = normalize(&mut zn);
        let d = self.d as f32;
        let mut levels = vec![0i16; self.k];
        if norm == 0.0 {
            // degenerate zero factor: put it on the first axis
            levels[0] = 1;
            return TessVector { levels, d: self.d };
        }
        let mut all_zero = true;
        for (li, &zi) in levels.iter_mut().zip(zn.iter()) {
            // steps 5-11: |Dz - ceil| vs |Dz - floor| == round-half-up;
            // f32::round (half away from zero) matches jnp.round on the
            // golden set within grid tolerance.
            let l = (zi * d).round() as i32;
            *li = l.clamp(-(self.d as i32), self.d as i32) as i16;
            if *li != 0 {
                all_zero = false;
            }
        }
        if all_zero {
            // A_D excludes {0}^k: snap the max-|z| coordinate to ±1 level
            // (same rule as the pallas kernel).
            let (idx, _) = zn
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| {
                    a.abs().partial_cmp(&b.abs()).unwrap_or(std::cmp::Ordering::Equal)
                })
                .expect("k > 0");
            levels[idx] = if zn[idx].is_sign_negative() { -1 } else { 1 };
        }
        TessVector { levels, d: self.d }
    }

    fn name(&self) -> &'static str {
        "dary"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::angular_distance;
    use crate::tessellation::{brute_force_assign, TernaryTessellation};
    use crate::testing::prop;

    #[test]
    fn epsilon_bound_lemma2() {
        // ‖z - ã_z‖ ≤ √k / D before normalisation ⇒ d(a_z, z) small; check
        // the end-to-end angular gap vs the brute-force optimum is O(k/D²)
        // with a conservative constant.
        prop(60, |g| {
            let k = g.usize_in(2..=4);
            let d = *g.choose(&[2u32, 3, 4]);
            let z = g.unit_vector(k);
            let approx = DaryTessellation::new(k, d).assign(&z);
            let exact = brute_force_assign(&z, d);
            let d_approx = angular_distance(&approx.to_unit(), &z);
            let d_exact = angular_distance(&exact.to_unit(), &z);
            let eps = 8.0 * k as f32 / (d * d) as f32; // constant from Lemma 2 proof
            assert!(
                d_approx - d_exact <= eps,
                "gap {} > eps {eps} (k={k}, D={d})",
                d_approx - d_exact
            );
        });
    }

    #[test]
    fn levels_within_grid_bounds() {
        prop(100, |g| {
            let k = g.usize_in(1..=32);
            let d = *g.choose(&[1u32, 2, 4, 8, 16]);
            let z = g.vec_gaussian(k..=k);
            let t = DaryTessellation::new(k, d).assign(&z);
            assert!(t.levels.iter().all(|&l| l.unsigned_abs() as u32 <= d));
            assert!(t.support() >= 1, "output must be in Γ (non-zero)");
        });
    }

    #[test]
    fn scale_invariance() {
        prop(60, |g| {
            let k = g.usize_in(2..=16);
            let d = *g.choose(&[2u32, 8]);
            let z = g.unit_vector(k);
            let s = g.f32_in(0.05, 30.0);
            let zs: Vec<f32> = z.iter().map(|v| v * s).collect();
            let tess = DaryTessellation::new(k, d);
            assert_eq!(tess.assign(&z).levels, tess.assign(&zs).levels);
        });
    }

    #[test]
    fn zero_factor_gets_axis() {
        let t = DaryTessellation::new(4, 8).assign(&[0.0; 4]);
        assert_eq!(t.levels, vec![1, 0, 0, 0]);
    }

    #[test]
    fn tiny_coordinates_snap_max() {
        // all |z_i| < 1/(2D) after normalisation is impossible for unit z
        // (‖z‖=1 forces a coordinate ≥ 1/√k ≥ 1/(2D) when D ≥ √k/2), so
        // exercise the snap path via the unnormalised degenerate input.
        let z = [1e-4f32, -3e-4, 2e-4, 1e-4];
        // normalised this is fine; force the snap by using D=1 and a vector
        // whose normalised coords are all < 0.5 in magnitude:
        let z2 = [0.45f32, -0.45, 0.45, 0.45, 0.45]; // norm ≈ 1.006
        let t = DaryTessellation::new(5, 1).assign(&z2);
        assert!(t.support() >= 1);
        let t2 = DaryTessellation::new(4, 8).assign(&z);
        assert!(t2.support() >= 1);
    }

    #[test]
    fn finer_grid_is_closer() {
        // increasing D must not increase the angular distance (statistically;
        // we assert on the mean over many draws).
        let mut gap2 = 0.0f64;
        let mut gap16 = 0.0f64;
        let mut g = crate::rng::Rng::seeded(99);
        for _ in 0..200 {
            let mut z: Vec<f32> = (0..8).map(|_| g.gaussian_f32()).collect();
            crate::geometry::normalize(&mut z);
            gap2 += angular_distance(
                &DaryTessellation::new(8, 2).assign(&z).to_unit(),
                &z,
            ) as f64;
            gap16 += angular_distance(
                &DaryTessellation::new(8, 16).assign(&z).to_unit(),
                &z,
            ) as f64;
        }
        assert!(gap16 < gap2, "finer grid should be closer: {gap16} vs {gap2}");
    }

    #[test]
    fn dary1_close_to_exact_ternary() {
        // D=1 rounding is the approximate version of Algorithm 2; the
        // angular gap must stay within the Lemma-2 envelope.
        prop(60, |g| {
            let k = g.usize_in(2..=8);
            let z = g.unit_vector(k);
            let approx = DaryTessellation::new(k, 1).assign(&z);
            let exact = TernaryTessellation::new(k).assign(&z);
            let da = angular_distance(&approx.to_unit(), &z);
            let de = angular_distance(&exact.to_unit(), &z);
            assert!(da + 1e-6 >= de, "exact must be at least as close");
            assert!(da - de <= 8.0 * k as f32, "sanity envelope");
        });
    }
}
