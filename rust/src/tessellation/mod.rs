//! Tessellations of the unit sphere (paper §4.1).
//!
//! A tessellation assigns every factor `z ∈ R^k` to its closest (in angular
//! distance) tessellating vector `a ∈ Γ` — without ever materialising Γ,
//! which has `|Γ| = 3^k - 1` (ternary) or `(2D+1)^k - 1` (D-ary) elements.
//!
//! * [`TernaryTessellation`] — paper Algorithm 2: exact in O(k log k).
//! * [`DaryTessellation`] — supplement Algorithm 3: ε-approximate in O(k)
//!   with ε ~ O(k/D²) (Lemma 2).
//! * [`ClusterAdaptive`] — the paper §5 clustered-data extension: D-ary
//!   resolution near cluster centres, ternary elsewhere (a §B.1 drop-list
//!   over Γ_D).
//! * [`CappedTernary`] — the supplement §B.1 non-uniform variant obtained
//!   by *dropping* tessellating vectors (here: all vectors with support
//!   larger than `t_max`), still exact over the retained set.
//! * [`brute_force_assign`] — test oracle that enumerates Γ for small k.

mod adaptive;
mod dary;
mod ternary;

pub use adaptive::ClusterAdaptive;
pub use dary::DaryTessellation;
pub use ternary::{CappedTernary, TernaryTessellation};

use crate::geometry::normalize;

/// An (unnormalised) tessellating vector ã: integer levels in units of
/// `1/d`, so the represented vector is `levels / d`, normalised.
///
/// Ternary vectors are the `d = 1` case with levels in {-1, 0, 1}.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct TessVector {
    /// Per-coordinate level; level ∈ [-d, d].
    pub levels: Vec<i16>,
    /// Grid resolution D (≥ 1).
    pub d: u32,
}

impl TessVector {
    /// Support size (number of non-zero levels) — `t` in the paper.
    pub fn support(&self) -> usize {
        self.levels.iter().filter(|&&l| l != 0).count()
    }

    /// The normalised tessellating vector `a = ã / ‖ã‖` as dense f32.
    pub fn to_unit(&self) -> Vec<f32> {
        let mut v: Vec<f32> =
            self.levels.iter().map(|&l| l as f32 / self.d as f32).collect();
        normalize(&mut v);
        v
    }

    /// A stable 64-bit region id (FNV-1a over levels + d). Two factors in
    /// the same Voronoi tile share a region id.
    pub fn region_id(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        };
        for b in self.d.to_le_bytes() {
            mix(b);
        }
        for &l in &self.levels {
            for b in l.to_le_bytes() {
                mix(b);
            }
        }
        h
    }

    /// ℓ1 distance between unnormalised vectors, in grid units — the
    /// quantity that §4.2.1 ties to Kendall-tau distance of the
    /// corresponding permutations.
    pub fn l1_grid_distance(&self, other: &TessVector) -> u32 {
        assert_eq!(self.d, other.d, "grid resolutions differ");
        assert_eq!(self.levels.len(), other.levels.len());
        self.levels
            .iter()
            .zip(&other.levels)
            .map(|(&a, &b)| (a as i32 - b as i32).unsigned_abs())
            .sum()
    }
}

/// A deterministic function-based tessellation schema (paper §3.3: no
/// explicit storage of Γ, assignment is a function of `z` alone).
pub trait Tessellation: Send + Sync {
    /// Factor dimensionality k.
    fn k(&self) -> usize;

    /// Grid resolution D of the produced [`TessVector`]s.
    fn d(&self) -> u32;

    /// Closest (or ε-closest) tessellating vector for `z`.
    ///
    /// Scale-invariant in `z` (paper §5). `z.len()` must equal `self.k()`.
    fn assign(&self, z: &[f32]) -> TessVector;

    /// Human-readable schema name for logs/reports.
    fn name(&self) -> &'static str;
}

/// Test oracle: exact argmax over the full tessellating set Γ_D by
/// enumeration — `(2d+1)^k - 1` candidates, so only usable for tiny k/d.
///
/// Returns the unnormalised levels of the argmax of `cos(a, z)`.
pub fn brute_force_assign(z: &[f32], d: u32) -> TessVector {
    let k = z.len();
    let base = (2 * d + 1) as u64;
    let total = base.checked_pow(k as u32).expect("enumeration overflow");
    assert!(total <= 1 << 26, "brute force too large: {total}");
    let mut best: Option<(f64, Vec<i16>)> = None;
    let mut levels = vec![0i16; k];
    // code 0 decodes to all-(-d), NOT the all-zero vector — the zero
    // vector is skipped by the explicit guard below, so enumerate from 0.
    for code in 0..total {
        // decode mixed-radix representation
        let mut c = code;
        for l in levels.iter_mut() {
            *l = (c % base) as i16 - d as i16;
            c /= base;
        }
        if levels.iter().all(|&l| l == 0) {
            continue;
        }
        let mut dot = 0.0f64;
        let mut nrm = 0.0f64;
        for (zi, &li) in z.iter().zip(levels.iter()) {
            let a = li as f64 / d as f64;
            dot += a * *zi as f64;
            nrm += a * a;
        }
        let cos = dot / nrm.sqrt();
        if best.as_ref().map(|(b, _)| cos > *b).unwrap_or(true) {
            best = Some((cos, levels.clone()));
        }
    }
    TessVector { levels: best.expect("nonempty Γ").1, d }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tess_vector_support_and_unit() {
        let t = TessVector { levels: vec![1, 0, -1, 1], d: 1 };
        assert_eq!(t.support(), 3);
        let u = t.to_unit();
        let inv = 1.0 / 3.0f32.sqrt();
        assert!((u[0] - inv).abs() < 1e-6);
        assert!((u[2] + inv).abs() < 1e-6);
        assert_eq!(u[1], 0.0);
    }

    #[test]
    fn region_ids_differ_for_different_levels() {
        let a = TessVector { levels: vec![1, 0, 1], d: 1 };
        let b = TessVector { levels: vec![1, 1, 0], d: 1 };
        let c = TessVector { levels: vec![1, 0, 1], d: 2 };
        assert_ne!(a.region_id(), b.region_id());
        assert_ne!(a.region_id(), c.region_id());
        assert_eq!(a.region_id(), a.clone().region_id());
    }

    #[test]
    fn l1_grid_distance_counts_level_changes() {
        let a = TessVector { levels: vec![1, 0, -1], d: 1 };
        let b = TessVector { levels: vec![0, 0, 1], d: 1 };
        assert_eq!(a.l1_grid_distance(&b), 3);
        assert_eq!(a.l1_grid_distance(&a), 0);
    }

    #[test]
    fn brute_force_prefers_aligned_vector() {
        // z along axis 1 → best ternary vector is e1
        let z = [0.05f32, 0.98, -0.02];
        let t = brute_force_assign(&z, 1);
        assert_eq!(t.levels, vec![0, 1, 0]);
    }

    #[test]
    fn brute_force_uniform_vector_full_support() {
        let z = [0.5f32, 0.5, 0.5, 0.5];
        let t = brute_force_assign(&z, 1);
        assert_eq!(t.levels, vec![1, 1, 1, 1]);
    }
}
