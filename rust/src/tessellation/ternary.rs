//! Ternary directional tessellation — paper §4.1.1, Algorithm 2.
//!
//! Γ is the set of normalised non-zero vectors over the base set
//! {-1, 0, 1}; `|Γ| = 3^k - 1`. Algorithm 2 finds the *exact* closest
//! tessellating vector in O(k log k): the footnote warns that naïve
//! per-coordinate thresholding at ±0.5 is NOT exact under angular
//! distance, which is why the scaled-cumsum search over support sizes is
//! needed.

use super::{TessVector, Tessellation};

/// Exact ternary tessellation (Algorithm 2).
#[derive(Clone, Debug)]
pub struct TernaryTessellation {
    k: usize,
}

impl TernaryTessellation {
    /// Tessellation for k-dimensional factors.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        TernaryTessellation { k }
    }
}

/// Core of Algorithm 2, shared with [`CappedTernary`]: find the optimal
/// support size `t* ≤ t_max` and return the corresponding levels.
///
/// Steps (paper numbering):
///  2-3. sort coordinates by |z| descending (stable ⇒ deterministic ties);
///  4-7. scaled cumulative sums  z_s^ι = (Σ_{j≤ι} |z|_(j)) / √ι;
///  8.   ι* = argmax_ι z_s^ι  (restricted to ι ≤ t_max);
///  9-10. support = top-ι* coordinates, levels = sign(z) there.
fn assign_capped(z: &[f32], t_max: usize) -> TessVector {
    let k = z.len();
    debug_assert!(t_max >= 1 && t_max <= k);
    // sort indices by |z| descending; stable tie-break on index keeps the
    // map deterministic for equal magnitudes.
    let mut order: Vec<u32> = (0..k as u32).collect();
    order.sort_by(|&a, &b| {
        let ma = z[a as usize].abs();
        let mb = z[b as usize].abs();
        mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    // scaled cumsum argmax in f64 for stability on large k
    let mut best_t = 1usize;
    let mut best_score = f64::NEG_INFINITY;
    let mut acc = 0.0f64;
    for (i, &idx) in order.iter().take(t_max).enumerate() {
        acc += z[idx as usize].abs() as f64;
        let score = acc / ((i + 1) as f64).sqrt();
        if score > best_score {
            best_score = score;
            best_t = i + 1;
        }
    }
    let mut levels = vec![0i16; k];
    for &idx in order.iter().take(best_t) {
        // sign(0) → +1: a zero coordinate can only enter the support when
        // the whole vector is zero; +1 keeps the output in Γ (non-zero).
        levels[idx as usize] = if z[idx as usize] < 0.0 { -1 } else { 1 };
    }
    TessVector { levels, d: 1 }
}

impl Tessellation for TernaryTessellation {
    fn k(&self) -> usize {
        self.k
    }

    fn d(&self) -> u32 {
        1
    }

    fn assign(&self, z: &[f32]) -> TessVector {
        assert_eq!(z.len(), self.k, "factor dim {} != k {}", z.len(), self.k);
        assign_capped(z, self.k)
    }

    fn name(&self) -> &'static str {
        "ternary"
    }
}

/// Non-uniform tessellation (supplement §B.1): the ternary schema with all
/// tessellating vectors of support > `t_max` *dropped*.
///
/// Dropping dense-support vectors coarsens the tessellation near orthant
/// centres (where §B.1 shows Γ is most densely packed) while keeping full
/// resolution along the axes — the "drop some tessellating vectors"
/// construction, realised deterministically. Algorithm 2 restricted to
/// ι ≤ t_max remains *exact* over the retained set because the optimal
/// support for any fixed size is still the top-|z| prefix.
#[derive(Clone, Debug)]
pub struct CappedTernary {
    k: usize,
    t_max: usize,
}

impl CappedTernary {
    /// Ternary tessellation retaining only vectors with support ≤ `t_max`.
    pub fn new(k: usize, t_max: usize) -> Self {
        assert!(k > 0 && (1..=k).contains(&t_max), "need 1 <= t_max <= k");
        CappedTernary { k, t_max }
    }
}

impl Tessellation for CappedTernary {
    fn k(&self) -> usize {
        self.k
    }

    fn d(&self) -> u32 {
        1
    }

    fn assign(&self, z: &[f32]) -> TessVector {
        assert_eq!(z.len(), self.k, "factor dim {} != k {}", z.len(), self.k);
        assign_capped(z, self.t_max)
    }

    fn name(&self) -> &'static str {
        "ternary-capped"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::angular_distance;
    use crate::tessellation::brute_force_assign;
    use crate::testing::prop;

    #[test]
    fn exactness_vs_brute_force_small_k() {
        // Lemma 1: Algorithm 2 solves eq. (1) exactly.
        prop(150, |g| {
            let k = g.usize_in(2..=7);
            let z = g.unit_vector(k);
            let tess = TernaryTessellation::new(k);
            let fast = tess.assign(&z);
            let brute = brute_force_assign(&z, 1);
            let d_fast = angular_distance(&fast.to_unit(), &z);
            let d_brute = angular_distance(&brute.to_unit(), &z);
            assert!(
                d_fast <= d_brute + 1e-5,
                "fast {:?} (d={d_fast}) worse than brute {:?} (d={d_brute}) for {z:?}",
                fast.levels,
                brute.levels
            );
        });
    }

    #[test]
    fn naive_thresholding_is_not_exact() {
        // The paper's footnote 5: thresholding each coordinate at ±0.5 is
        // not the angular-distance argmin. Exhibit a witness.
        let z = [0.6f32, 0.45, 0.45, 0.45];
        let tess = TernaryTessellation::new(4);
        let ours = tess.assign(&z);
        // naive: [1,0,0,0] (only 0.6 > 0.5)
        let naive = TessVector { levels: vec![1, 0, 0, 0], d: 1 };
        let d_ours = angular_distance(&ours.to_unit(), &z);
        let d_naive = angular_distance(&naive.to_unit(), &z);
        assert!(d_ours < d_naive, "ours {d_ours} naive {d_naive}");
        assert_eq!(ours.levels, vec![1, 1, 1, 1]);
    }

    #[test]
    fn scale_invariance() {
        prop(100, |g| {
            let k = g.usize_in(2..=32);
            let z = g.unit_vector(k);
            let s = g.f32_in(0.05, 20.0);
            let zs: Vec<f32> = z.iter().map(|v| v * s).collect();
            let tess = TernaryTessellation::new(k);
            assert_eq!(tess.assign(&z).levels, tess.assign(&zs).levels);
        });
    }

    #[test]
    fn signs_match_input() {
        prop(100, |g| {
            let k = g.usize_in(2..=16);
            let z = g.unit_vector(k);
            let t = TernaryTessellation::new(k).assign(&z);
            for (zi, &li) in z.iter().zip(&t.levels) {
                if li != 0 {
                    assert!(
                        (*zi >= 0.0 && li > 0) || (*zi <= 0.0 && li < 0),
                        "level sign disagrees with coordinate"
                    );
                }
            }
            assert!(t.support() >= 1);
        });
    }

    #[test]
    fn support_is_top_magnitude_prefix() {
        prop(100, |g| {
            let k = g.usize_in(2..=16);
            let z = g.unit_vector(k);
            let t = TernaryTessellation::new(k).assign(&z);
            let min_in = z
                .iter()
                .zip(&t.levels)
                .filter(|(_, &l)| l != 0)
                .map(|(v, _)| v.abs())
                .fold(f32::INFINITY, f32::min);
            let max_out = z
                .iter()
                .zip(&t.levels)
                .filter(|(_, &l)| l == 0)
                .map(|(v, _)| v.abs())
                .fold(0.0f32, f32::max);
            assert!(min_in >= max_out - 1e-6);
        });
    }

    #[test]
    fn dominant_axis_gets_singleton_support() {
        let z = [0.99f32, 0.01, 0.0, -0.01];
        let t = TernaryTessellation::new(4).assign(&z);
        assert_eq!(t.levels, vec![1, 0, 0, 0]);
    }

    #[test]
    fn capped_limits_support() {
        prop(100, |g| {
            let k = g.usize_in(3..=16);
            let t_max = g.usize_in(1..=k);
            let z = g.unit_vector(k);
            let t = CappedTernary::new(k, t_max).assign(&z);
            assert!(t.support() <= t_max);
            assert!(t.support() >= 1);
        });
    }

    #[test]
    fn capped_with_full_cap_equals_uncapped() {
        prop(50, |g| {
            let k = g.usize_in(2..=12);
            let z = g.unit_vector(k);
            let a = TernaryTessellation::new(k).assign(&z);
            let b = CappedTernary::new(k, k).assign(&z);
            assert_eq!(a.levels, b.levels);
        });
    }

    #[test]
    fn capped_is_exact_over_retained_set() {
        // brute force restricted to support <= t_max must not beat it
        prop(80, |g| {
            let k = g.usize_in(2..=6);
            let t_max = g.usize_in(1..=k);
            let z = g.unit_vector(k);
            let ours = CappedTernary::new(k, t_max).assign(&z);
            let d_ours = angular_distance(&ours.to_unit(), &z);
            // enumerate retained Γ
            let mut best = f32::INFINITY;
            let mut levels = vec![0i16; k];
            let total = 3u64.pow(k as u32);
            for code in 1..total {
                let mut c = code;
                for l in levels.iter_mut() {
                    *l = (c % 3) as i16 - 1;
                    c /= 3;
                }
                let sup = levels.iter().filter(|&&l| l != 0).count();
                if sup == 0 || sup > t_max {
                    continue;
                }
                let t = TessVector { levels: levels.clone(), d: 1 };
                best = best.min(angular_distance(&t.to_unit(), &z));
            }
            assert!(d_ours <= best + 1e-5, "capped not exact: {d_ours} vs {best}");
        });
    }

    #[test]
    #[should_panic(expected = "factor dim")]
    fn dim_mismatch_panics() {
        TernaryTessellation::new(4).assign(&[1.0, 2.0]);
    }
}
