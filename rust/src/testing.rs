//! Test support: a seeded property runner (proptest is unavailable
//! offline — `docs/ARCHITECTURE.md` §Offline substitutions) plus the
//! shared synthetic fixtures ([`fix`])
//! the integration tests and bench targets build their workloads from.
//!
//! A deliberately small, seeded property runner:
//!
//! ```no_run
//! use geomap::testing::{prop, Gen};
//! prop(200, |g: &mut Gen| {
//!     let xs = g.vec_f32(1..=32, -1.0, 1.0);
//!     let sum: f32 = xs.iter().sum();
//!     let sum2: f32 = xs.iter().rev().sum();
//!     assert!((sum - sum2).abs() < 1e-3);
//! });
//! ```
//!
//! On failure the panic message includes the case seed; re-run a single
//! case with [`prop_seeded`]. No shrinking — cases are kept small instead.

use crate::rng::Rng;

pub mod fix {
    //! Seeded synthetic catalogue/query fixtures shared by the
    //! integration tests and bench targets (extracted from per-file
    //! copies). Every factor/query builder is deterministic in its
    //! `seed` with random streams byte-identical to the historical
    //! in-file helpers, so migrating those call sites never changes a
    //! test's inputs. [`serve_cfg`] is a *normalized* baseline, not a
    //! stream: tests that relied on specific batching/queue knobs
    //! override the returned fields explicitly.

    use crate::configx::{Backend, SchemaConfig, ServeConfig};
    use crate::linalg::Matrix;
    use crate::rng::Rng;

    /// N(0,1) item catalogue: `n × k`, deterministic in `seed`.
    pub fn items(n: usize, k: usize, seed: u64) -> Matrix {
        let mut rng = Rng::seeded(seed);
        Matrix::gaussian(&mut rng, n, k, 1.0)
    }

    /// N(0,1) query block: `b × k` user factors, deterministic in `seed`
    /// (row `r` is a batch lane for the batched retrieval paths).
    pub fn users(b: usize, k: usize, seed: u64) -> Matrix {
        items(b, k, seed)
    }

    /// One N(0,1) user factor.
    pub fn user(k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seeded(seed);
        (0..k).map(|_| rng.gaussian_f32()).collect()
    }

    /// `n` user factors as owned vectors, drawn from one stream.
    pub fn user_vecs(n: usize, k: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seeded(seed);
        (0..n)
            .map(|_| (0..k).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    /// Paired (users, items) factors drawn from ONE seeded stream, users
    /// first — byte-identical to the historical bench workload builder.
    pub fn workload(
        n_users: usize,
        n_items: usize,
        k: usize,
        seed: u64,
    ) -> (Matrix, Matrix) {
        let mut rng = Rng::seeded(seed);
        (
            Matrix::gaussian(&mut rng, n_users, k, 1.0),
            Matrix::gaussian(&mut rng, n_items, k, 1.0),
        )
    }

    /// The six pruning backends at test-sized §6 parameters — the list
    /// every backend-sweep test iterates.
    pub fn all_backends() -> [Backend; 6] {
        [
            Backend::Geomap,
            Backend::Srp { bits: 3, tables: 2 },
            Backend::Superbit { bits: 3, depth: 3, tables: 2 },
            Backend::Cros { m: 12, l: 1, tables: 2 },
            Backend::PcaTree { leaf_frac: 0.25 },
            Backend::Brute,
        ]
    }

    /// A small CPU-scorer serving config for coordinator tests
    /// (schema-parameterized via the returned value's fields; unset
    /// knobs keep their `ServeConfig::default()` values).
    pub fn serve_cfg(
        k: usize,
        shards: usize,
        backend: Backend,
        threshold: f32,
    ) -> ServeConfig {
        ServeConfig {
            k,
            kappa: 10,
            schema: SchemaConfig::TernaryParseTree,
            max_batch: 16,
            max_wait_us: 200,
            shards,
            queue_cap: 1024,
            use_xla: false,
            threshold,
            backend,
            ..ServeConfig::default()
        }
    }
}

/// Random-input generator handed to property bodies.
pub struct Gen {
    rng: Rng,
    /// Seed of the current case (embedded in failure messages).
    pub case_seed: u64,
}

impl Gen {
    /// Integer in the inclusive range.
    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below(hi - lo + 1)
    }

    /// f32 uniform in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f64(lo as f64, hi as f64) as f32
    }

    /// Standard normal f32.
    pub fn gaussian(&mut self) -> f32 {
        self.rng.gaussian_f32()
    }

    /// Vector of uniform f32s with random length from `len`.
    pub fn vec_f32(
        &mut self,
        len: std::ops::RangeInclusive<usize>,
        lo: f32,
        hi: f32,
    ) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vector of standard normals with random length.
    pub fn vec_gaussian(&mut self, len: std::ops::RangeInclusive<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.gaussian()).collect()
    }

    /// Unit-norm gaussian direction in R^k (rejects near-zero draws).
    pub fn unit_vector(&mut self, k: usize) -> Vec<f32> {
        loop {
            let v: Vec<f32> = (0..k).map(|_| self.gaussian()).collect();
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            if n > 1e-3 {
                return v.into_iter().map(|x| x / n).collect();
            }
        }
    }

    /// Bernoulli(p).
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.uniform() < p
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Access the underlying RNG for custom generation.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `body` on `cases` random inputs derived from a fixed master seed
/// (deterministic across runs; override with env `GEOMAP_PROP_SEED`).
pub fn prop(cases: usize, body: impl Fn(&mut Gen)) {
    let master = std::env::var("GEOMAP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut seeder = Rng::seeded(master);
    for case in 0..cases {
        let case_seed = seeder.next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Rng::seeded(case_seed), case_seed };
            body(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed at case {case}/{cases} (seed {case_seed:#x}): {msg}\n\
                 reproduce with geomap::testing::prop_seeded({case_seed:#x}, body)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn prop_seeded(case_seed: u64, body: impl Fn(&mut Gen)) {
    let mut g = Gen { rng: Rng::seeded(case_seed), case_seed };
    body(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_passes_trivial_property() {
        prop(50, |g| {
            let n = g.usize_in(1..=10);
            assert!((1..=10).contains(&n));
        });
    }

    #[test]
    fn prop_reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            prop(10, |g| {
                let v = g.usize_in(0..=100);
                assert!(v < 1000, "impossible");
                panic!("forced failure {v}");
            })
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn unit_vector_is_unit() {
        prop(50, |g| {
            let k = g.usize_in(1..=64);
            let v = g.unit_vector(k);
            let n = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        });
    }

    #[test]
    fn prop_is_deterministic() {
        use std::sync::Mutex;
        let seen1 = Mutex::new(Vec::new());
        prop(5, |g| seen1.lock().unwrap().push(g.case_seed));
        let seen2 = Mutex::new(Vec::new());
        prop(5, |g| seen2.lock().unwrap().push(g.case_seed));
        assert_eq!(*seen1.lock().unwrap(), *seen2.lock().unwrap());
    }
}
