//! ISSUE 4 acceptance gate: batched candidate generation is
//! **order-insensitively identical** to the sequential path on every
//! backend × posting arena × batch size, and `top_k_batch` matches
//! `top_k` exactly (ids + bit-identical scores) — including while the
//! catalogue holds tombstoned and delta-segment items mid-mutation.
//!
//! Run under `cargo test --release` too (CI does): the term-major lane
//! counters use saturating arithmetic whose wrap-adjacent behaviour
//! debug assertions would otherwise mask.

use geomap::configx::{
    Backend, MutationConfig, PostingsMode, QuantMode, SchemaConfig,
};
use geomap::engine::{BatchCandidates, Engine, SourceScratch};
use geomap::linalg::Matrix;
use geomap::testing::{fix, prop};

/// The spec'd batch sizes: singleton, tiny, odd, the serving default
/// (= the term-major lane width), and several lane chunks plus a tail.
const BATCH_SIZES: [usize; 5] = [1, 2, 7, 32, 129];

fn sorted(v: &[u32]) -> Vec<u32> {
    let mut v = v.to_vec();
    v.sort_unstable();
    v
}

/// The full equivalence contract for one engine × one query block.
fn assert_batch_matches_sequential(engine: &Engine, users: &Matrix, tag: &str) {
    let mut scratch = SourceScratch::new();
    let mut cand = BatchCandidates::new();
    engine.candidates_batch_into(users, &mut scratch, &mut cand).unwrap();
    assert_eq!(cand.queries(), users.rows(), "{tag}: batch shape");
    for r in 0..users.rows() {
        let batch = sorted(cand.query(r));
        assert!(
            batch.windows(2).all(|w| w[0] < w[1]),
            "{tag}: query {r} emitted duplicate ids"
        );
        let seq = engine.candidates(users.row(r)).unwrap();
        assert_eq!(batch, seq, "{tag}: query {r} candidate sets diverge");
    }
    // the escape-hatch reference loop agrees as well
    let mut seq_arena = BatchCandidates::new();
    engine
        .candidates_batch_seq(users, &mut scratch, &mut seq_arena)
        .unwrap();
    for r in 0..users.rows() {
        assert_eq!(
            sorted(cand.query(r)),
            sorted(seq_arena.query(r)),
            "{tag}: query {r} batch vs per-query arena"
        );
    }
    // top_k_batch == top_k: same ids, bit-identical scores
    let kappa = 7;
    let batch_top = engine.top_k_batch(users, kappa).unwrap();
    assert_eq!(batch_top.len(), users.rows(), "{tag}");
    for r in 0..users.rows() {
        let single = engine.top_k(users.row(r), kappa).unwrap();
        assert_eq!(batch_top[r].len(), single.len(), "{tag}: query {r} len");
        for (x, y) in batch_top[r].iter().zip(&single) {
            assert_eq!(x.id, y.id, "{tag}: query {r} top-k ids");
            assert_eq!(
                x.score.to_bits(),
                y.score.to_bits(),
                "{tag}: query {r} top-k scores not byte-exact"
            );
        }
    }
}

/// All 6 backends × {raw, packed} (packed is geomap-only by config) ×
/// the spec'd batch sizes, on catalogues below and above the packed
/// 128-entry block boundary.
#[test]
fn batch_equals_sequential_on_all_backends_and_arenas() {
    for (n, k, seed) in [(60usize, 6usize, 1u64), (300, 8, 2)] {
        let items = fix::items(n, k, seed);
        for backend in fix::all_backends() {
            let arenas: &[PostingsMode] = if matches!(backend, Backend::Geomap)
            {
                &[PostingsMode::Raw, PostingsMode::Packed]
            } else {
                &[PostingsMode::Raw]
            };
            for &postings in arenas {
                let engine = Engine::builder()
                    .backend(backend)
                    .threshold(0.5)
                    .postings(postings)
                    .build(items.clone())
                    .unwrap();
                for &bsz in &BATCH_SIZES {
                    let users = fix::users(bsz, k, 100 + bsz as u64);
                    assert_batch_matches_sequential(
                        &engine,
                        &users,
                        &format!(
                            "{}/{}/n={n}/B={bsz}",
                            engine.label(),
                            postings.spec()
                        ),
                    );
                }
            }
        }
    }
}

/// Mid-mutation equivalence: tombstones, superseded base rows, delta
/// rows and appends all pending (unmerged) — then again after a merge.
#[test]
fn batch_equals_sequential_mid_mutation() {
    let k = 8;
    for postings in [PostingsMode::Raw, PostingsMode::Packed] {
        let mut engine = Engine::builder()
            .threshold(0.0)
            .postings(postings)
            .mutation(MutationConfig { max_delta: 0 }) // manual merge only
            .build(fix::items(150, k, 3))
            .unwrap();
        engine.remove(7).unwrap();
        engine.remove(128).unwrap(); // lives in the second packed block
        engine.upsert(11, &fix::user(k, 900)).unwrap(); // supersede
        engine.upsert(150, &fix::user(k, 901)).unwrap(); // append
        engine.upsert(151, &fix::user(k, 902)).unwrap(); // append
        assert!(engine.pending() > 0, "mutations must be unmerged");
        for &bsz in &BATCH_SIZES {
            let users = fix::users(bsz, k, 200 + bsz as u64);
            let tag = format!("mid-mutation/{}/B={bsz}", postings.spec());
            assert_batch_matches_sequential(&engine, &users, &tag);
            // removed ids never surface in any lane
            let mut scratch = SourceScratch::new();
            let mut cand = BatchCandidates::new();
            engine
                .candidates_batch_into(&users, &mut scratch, &mut cand)
                .unwrap();
            assert!(
                cand.all_ids().iter().all(|&id| id != 7 && id != 128),
                "{tag}: tombstoned id resurfaced"
            );
        }
        engine.merge().unwrap();
        assert_eq!(engine.pending(), 0);
        for &bsz in &[2usize, 32, 129] {
            let users = fix::users(bsz, k, 300 + bsz as u64);
            assert_batch_matches_sequential(
                &engine,
                &users,
                &format!("post-merge/{}/B={bsz}", postings.spec()),
            );
        }
    }
}

/// The quantized rescore path through `top_k_batch`: int8 scan + exact
/// refinement must return byte-identical results to the sequential call.
#[test]
fn quantized_top_k_batch_matches_top_k() {
    for postings in [PostingsMode::Raw, PostingsMode::Packed] {
        let engine = Engine::builder()
            .schema(SchemaConfig::TernaryOneHot)
            .threshold(0.5)
            .quant(QuantMode::Int8 { refine: 3 })
            .postings(postings)
            .build(fix::items(400, 16, 5))
            .unwrap();
        for &bsz in &[1usize, 7, 32] {
            let users = fix::users(bsz, 16, 400 + bsz as u64);
            assert_batch_matches_sequential(
                &engine,
                &users,
                &format!("quantized/{}/B={bsz}", postings.spec()),
            );
        }
    }
}

/// Seeded property sweep: random catalogues, schemas, thresholds,
/// min_overlap, posting arenas, random churn, random batch size.
#[test]
fn batch_equivalence_property() {
    prop(12, |g| {
        let k = g.usize_in(3..=12);
        let n = g.usize_in(1..=300);
        let postings = if g.bool_with(0.5) {
            PostingsMode::Packed
        } else {
            PostingsMode::Raw
        };
        let schema = *g.choose(&[
            SchemaConfig::TernaryParseTree,
            SchemaConfig::TernaryOneHot,
        ]);
        let mut engine = Engine::builder()
            .schema(schema)
            .threshold(g.f32_in(0.0, 1.5))
            .min_overlap(g.usize_in(1..=2))
            .postings(postings)
            .mutation(MutationConfig { max_delta: 0 })
            .build(fix::items(n, k, g.case_seed))
            .unwrap();
        if g.bool_with(0.7) {
            for step in 0..g.usize_in(1..=8) {
                let seed = g.case_seed ^ (step as u64 + 1);
                if g.bool_with(0.3) {
                    // ids never shrink, so len() >= n >= 1 holds
                    let id = g.usize_in(0..=engine.len() - 1) as u32;
                    let _ = engine.remove(id).unwrap();
                } else {
                    // id == len() appends; smaller ids replace
                    let id = g.usize_in(0..=engine.len()) as u32;
                    engine.upsert(id, &fix::user(k, seed)).unwrap();
                }
            }
        }
        let bsz = *g.choose(&[1usize, 2, 7, 32, 129]);
        let users = fix::users(bsz, k, g.case_seed ^ 0x55AA);
        assert_batch_matches_sequential(
            &engine,
            &users,
            &format!("prop/{}/{}/B={bsz}", schema.spec(), postings.spec()),
        );
    });
}
