//! Result-cache equivalence (docs/CACHE.md): a coordinator with
//! `cache: lru:<n>` must be *observably indistinguishable* from one with
//! `cache: off` — byte-identical results, candidate counts, catalogue
//! totals and versions for every backend — and a mutation between
//! repeated queries must always yield the post-mutation response (stale
//! entries are invalidated by shard mutation epochs, never served).

use geomap::configx::{Backend, CacheMode, PostingsMode, QuantMode, ServeConfig};
use geomap::coordinator::{Coordinator, Response};
use geomap::rng::Rng;
use geomap::runtime::cpu_scorer_factory;
use geomap::testing::fix;
use std::sync::atomic::Ordering;

/// Everything in a `Response` except latency, with scores at bit
/// precision ("byte-identical" is judged on this).
fn key(r: &Response) -> (Vec<(u32, u32)>, usize, usize, u64) {
    (
        r.results.iter().map(|s| (s.id, s.score.to_bits())).collect(),
        r.candidates,
        r.total_items,
        r.version,
    )
}

fn pair(
    mut cfg: ServeConfig,
    entries: usize,
    n: usize,
    seed: u64,
) -> (Coordinator, Coordinator) {
    let off = Coordinator::start(
        cfg.clone(),
        fix::items(n, cfg.k, seed),
        cpu_scorer_factory(),
    )
    .unwrap();
    cfg.cache = CacheMode::Lru { entries };
    let on = Coordinator::start(
        cfg.clone(),
        fix::items(n, cfg.k, seed),
        cpu_scorer_factory(),
    )
    .unwrap();
    (on, off)
}

#[test]
fn cached_matches_uncached_on_every_backend() {
    let k = 8;
    for backend in fix::all_backends() {
        let cfg = fix::serve_cfg(k, 2, backend, 0.5);
        let (on, off) = pair(cfg, 256, 300, 70);
        let users = fix::user_vecs(20, k, 71);
        // pass 0 fills the cache; pass 1 serves (mostly) from it — both
        // passes must be indistinguishable from the uncached coordinator
        for pass in 0..2 {
            for (i, u) in users.iter().enumerate() {
                let a = on.submit(u.clone(), 6).unwrap();
                let b = off.submit(u.clone(), 6).unwrap();
                assert_eq!(
                    key(&a),
                    key(&b),
                    "{backend:?}: pass {pass}, user {i}"
                );
            }
        }
        let m = on.metrics();
        assert_eq!(
            m.cache_hits.load(Ordering::Relaxed),
            20,
            "{backend:?}: second pass must be all hits"
        );
        assert_eq!(m.cache_stale.load(Ordering::Relaxed), 0);
        on.shutdown();
        off.shutdown();
    }
}

#[test]
fn cached_matches_uncached_with_quant_and_packed_postings() {
    // the fingerprint folds the engine-spec digest, so the compressed
    // tier caches like any other config — and stays byte-identical
    let k = 16;
    let mut cfg = fix::serve_cfg(k, 2, Backend::Geomap, 0.5);
    cfg.quant = QuantMode::Int8 { refine: 4 };
    cfg.postings = PostingsMode::Packed;
    let (on, off) = pair(cfg, 64, 400, 72);
    let users = fix::user_vecs(12, k, 73);
    for _ in 0..2 {
        for u in &users {
            let a = on.submit(u.clone(), 8).unwrap();
            let b = off.submit(u.clone(), 8).unwrap();
            assert_eq!(key(&a), key(&b));
        }
    }
    assert_eq!(on.metrics().cache_hits.load(Ordering::Relaxed), 12);
    on.shutdown();
    off.shutdown();
}

#[test]
fn interleaved_mutations_always_yield_post_mutation_results() {
    // seeded churn: after every upsert/append/remove applied to both
    // coordinators, a repeated query on the cached coordinator must
    // equal the uncached one — a stale hit would freeze the pre-mutation
    // response and fail the comparison
    let k = 8;
    let cfg = fix::serve_cfg(k, 2, Backend::Geomap, 0.0);
    let (on, off) = pair(cfg, 128, 200, 80);
    let pool = fix::user_vecs(8, k, 81);
    let compare_all = |label: &str| {
        for (i, u) in pool.iter().enumerate() {
            let a = on.submit(u.clone(), 5).unwrap();
            let b = off.submit(u.clone(), 5).unwrap();
            assert_eq!(key(&a), key(&b), "{label}, user {i}");
        }
    };
    compare_all("warm-up");
    let mut rng = Rng::seeded(82);
    for round in 0..25 {
        let total = on.total_items();
        assert_eq!(total, off.total_items());
        match rng.below(3) {
            0 => {
                // replace a random live-or-dead id in both
                let id = rng.below(total) as u32;
                let f: Vec<f32> =
                    (0..k).map(|_| rng.gaussian_f32()).collect();
                on.upsert(id, &f).unwrap();
                off.upsert(id, &f).unwrap();
            }
            1 => {
                // append
                let f: Vec<f32> =
                    (0..k).map(|_| rng.gaussian_f32()).collect();
                on.upsert(total as u32, &f).unwrap();
                off.upsert(total as u32, &f).unwrap();
            }
            _ => {
                let id = rng.below(total) as u32;
                let (_, a_live) = on.remove(id).unwrap();
                let (_, b_live) = off.remove(id).unwrap();
                assert_eq!(a_live, b_live);
            }
        }
        compare_all(&format!("round {round}"));
        // query the pool again so later rounds start from cache hits
        compare_all(&format!("round {round} (rewarm)"));
    }
    let m = on.metrics();
    assert!(
        m.cache_stale.load(Ordering::Relaxed) > 0,
        "churn must have invalidated cached entries"
    );
    assert!(
        m.cache_hits.load(Ordering::Relaxed) > 0,
        "rewarm passes must have produced hits"
    );
    on.shutdown();
    off.shutdown();
}

#[test]
fn tiny_cache_under_eviction_pressure_stays_equivalent() {
    // working set (16 users) far above capacity (3 entries): constant
    // admission/eviction churn through the segmented LRU must never
    // change a single response
    let k = 8;
    let cfg = fix::serve_cfg(k, 1, Backend::Geomap, 0.0);
    let (on, off) = pair(cfg, 3, 150, 90);
    let users = fix::user_vecs(16, k, 91);
    for _ in 0..4 {
        for u in &users {
            let a = on.submit(u.clone(), 4).unwrap();
            let b = off.submit(u.clone(), 4).unwrap();
            assert_eq!(key(&a), key(&b));
        }
    }
    let m = on.metrics();
    assert!(
        m.cache_evictions.load(Ordering::Relaxed) > 0,
        "a 3-entry cache under a 16-query working set must evict"
    );
    on.shutdown();
    off.shutdown();
}

#[test]
fn repeated_query_after_swap_serves_the_new_catalogue() {
    let k = 8;
    let cfg = fix::serve_cfg(k, 2, Backend::Geomap, 0.0);
    let (on, off) = pair(cfg, 64, 120, 92);
    let u = fix::user(k, 93);
    let before_on = on.submit(u.clone(), 5).unwrap();
    let before_off = off.submit(u.clone(), 5).unwrap();
    assert_eq!(key(&before_on), key(&before_off));
    // hit once, then replace the whole catalogue on both
    let _ = on.submit(u.clone(), 5).unwrap();
    on.swap_items(fix::items(90, k, 94)).unwrap();
    off.swap_items(fix::items(90, k, 94)).unwrap();
    let after_on = on.submit(u.clone(), 5).unwrap();
    let after_off = off.submit(u, 5).unwrap();
    assert_eq!(after_on.total_items, 90);
    assert_eq!(key(&after_on), key(&after_off), "swap must invalidate");
    assert_eq!(on.metrics().cache_stale.load(Ordering::Relaxed), 1);
    on.shutdown();
    off.shutdown();
}
