//! Engine-API acceptance tests: old-vs-new equivalence, all six backends
//! behind the coordinator by config, incremental mutation vs from-scratch
//! rebuild, `min_overlap > 1` semantics, and scratch survival across
//! catalogue growth.

use geomap::configx::{
    Backend, MutationConfig, PostingsMode, QuantMode, SchemaConfig, ServeConfig,
};
use geomap::coordinator::Coordinator;
use geomap::embedding::Mapper;
use geomap::engine::{Engine, SourceScratch};
use geomap::linalg::ops::dot;
use geomap::linalg::Matrix;
use geomap::retrieval::Retriever;
use geomap::runtime::cpu_scorer_factory;
use geomap::testing::fix::{items, user};

fn serve_cfg(k: usize, shards: usize, backend: Backend) -> ServeConfig {
    let mut c = geomap::testing::fix::serve_cfg(k, shards, backend, 0.0);
    // keep the historical tighter batching: 8-request splits exercise
    // more dynamic-batch boundaries than the fixture's default 16
    c.max_batch = 8;
    c.queue_cap = 256;
    c
}

/// cros-style equivalence: `Engine` top-κ over the geomap backend matches
/// the pre-redesign `Retriever::top_k` exactly — ids and bit-exact
/// scores — including with `min_overlap > 1`.
#[test]
fn engine_topk_matches_retriever_exactly() {
    let k = 8;
    let catalogue = items(300, k, 1);
    for (threshold, min_overlap) in [(0.0f32, 1usize), (1.0, 1), (0.5, 2)] {
        let engine = Engine::builder()
            .schema(SchemaConfig::TernaryParseTree)
            .threshold(threshold)
            .min_overlap(min_overlap)
            .build(catalogue.clone())
            .unwrap();
        let mapper =
            Mapper::from_config(SchemaConfig::TernaryParseTree, k, threshold);
        let mut retriever = Retriever::build(mapper, catalogue.clone()).unwrap();
        retriever.min_overlap = min_overlap;
        for s in 0..30u64 {
            let u = user(k, 100 + s);
            assert_eq!(
                engine.candidates(&u).unwrap(),
                retriever.candidates(&u).unwrap(),
                "threshold {threshold} min_overlap {min_overlap}"
            );
            let got = engine.top_k(&u, 10).unwrap();
            let want = retriever.top_k(&u, 10).unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.id, w.id);
                assert_eq!(g.score, w.score, "scores must match exactly");
            }
        }
    }
}

/// `min_overlap > 1` retrieval semantics at the engine level: exactly the
/// items whose φ-support overlaps the user's in ≥ m dimensions survive,
/// and raising m only shrinks the candidate set.
#[test]
fn min_overlap_semantics() {
    let k = 10;
    let catalogue = items(120, k, 2);
    let mapper = Mapper::from_config(SchemaConfig::TernaryParseTree, k, 0.0);
    let engines: Vec<Engine> = (1..=3)
        .map(|m| {
            Engine::builder()
                .schema(SchemaConfig::TernaryParseTree)
                .threshold(0.0)
                .min_overlap(m)
                .build(catalogue.clone())
                .unwrap()
        })
        .collect();
    for s in 0..15u64 {
        let u = user(k, 200 + s);
        let phi_u = mapper.map(&u).unwrap();
        let mut prev: Option<Vec<u32>> = None;
        for (mi, engine) in engines.iter().enumerate() {
            let m = mi + 1;
            let got = engine.candidates(&u).unwrap();
            // brute-force expectation from the φ embeddings
            let mut want = Vec::new();
            for r in 0..catalogue.rows() {
                let phi_i = mapper.map(catalogue.row(r)).unwrap();
                if phi_u.overlap(&phi_i) >= m {
                    want.push(r as u32);
                }
            }
            assert_eq!(got, want, "min_overlap {m}");
            if let Some(p) = &prev {
                assert!(
                    got.iter().all(|id| p.binary_search(id).is_ok()),
                    "raising min_overlap must only shrink the set"
                );
            }
            prev = Some(got);
        }
    }
}

/// All six backends are constructible through `Engine::builder()` and
/// servable through the coordinator, selected purely by config.
#[test]
fn six_backends_serve_through_coordinator_by_config() {
    let k = 8;
    let catalogue = items(240, k, 3);
    for backend in geomap::testing::fix::all_backends() {
        let coord = Coordinator::start(
            serve_cfg(k, 2, backend),
            catalogue.clone(),
            cpu_scorer_factory(),
        )
        .unwrap();
        for s in 0..8u64 {
            let u = user(k, 300 + s);
            let resp = coord.submit(u.clone(), 5).unwrap();
            assert!(resp.results.len() <= 5, "{backend:?}");
            assert!(resp.candidates <= 240);
            assert_eq!(resp.total_items, 240);
            for w in resp.results.windows(2) {
                assert!(w[0].score >= w[1].score);
            }
            // scores are exact inner products against the catalogue
            for r in &resp.results {
                let exact = dot(&u, catalogue.row(r.id as usize));
                assert!(
                    (r.score - exact).abs() < 1e-5,
                    "{backend:?}: inexact score"
                );
            }
        }
        coord.shutdown();
    }
}

/// Incremental mutation equivalence: a churned engine (upserts, appends,
/// removals) returns exactly what a from-scratch rebuild over the same
/// live items returns — before *and* after the delta merge.
#[test]
fn mutation_matches_from_scratch_rebuild() {
    let k = 8;
    let n0 = 120usize;
    let base = items(n0, k, 4);
    let spec = Engine::builder()
        .schema(SchemaConfig::TernaryParseTree)
        .threshold(0.0)
        .mutation(MutationConfig { max_delta: 0 }); // manual merge only
    let mut engine = spec.build(base.clone()).unwrap();

    // mirror of the live catalogue: id -> factor
    let mut truth: Vec<Option<Vec<f32>>> =
        (0..n0).map(|r| Some(base.row(r).to_vec())).collect();

    // churn: replacements, appends, removals (incl. remove-after-upsert)
    let apply_upsert = |engine: &mut Engine,
                            truth: &mut Vec<Option<Vec<f32>>>,
                            id: usize,
                            seed: u64| {
        let f = user(k, seed);
        engine.upsert(id as u32, &f).unwrap();
        if id == truth.len() {
            truth.push(Some(f));
        } else {
            truth[id] = Some(f);
        }
    };
    apply_upsert(&mut engine, &mut truth, 5, 1000);
    apply_upsert(&mut engine, &mut truth, 17, 1001);
    apply_upsert(&mut engine, &mut truth, 63, 1002);
    apply_upsert(&mut engine, &mut truth, 120, 1003);
    apply_upsert(&mut engine, &mut truth, 121, 1004);
    for id in [9u32, 17, 50] {
        assert!(engine.remove(id).unwrap());
        truth[id as usize] = None;
    }
    assert!(engine.pending() > 0, "churn must leave pending work");

    // from-scratch reference over the live items, with id -> rank map
    let live: Vec<(u32, &Vec<f32>)> = truth
        .iter()
        .enumerate()
        .filter_map(|(id, f)| f.as_ref().map(|f| (id as u32, f)))
        .collect();
    let mut dense = Matrix::zeros(live.len(), k);
    let mut rank = vec![u32::MAX; truth.len()];
    for (r, (id, f)) in live.iter().enumerate() {
        dense.row_mut(r).copy_from_slice(f);
        rank[*id as usize] = r as u32;
    }
    let reference = spec.build(dense).unwrap();

    let check = |engine: &Engine, phase: &str| {
        for s in 0..25u64 {
            let u = user(k, 400 + s);
            let got = engine.candidates(&u).unwrap();
            // removed ids never surface
            assert!(got.iter().all(|&id| truth[id as usize].is_some()), "{phase}");
            // candidate sets agree through the id -> rank bijection
            let mapped: Vec<u32> =
                got.iter().map(|&id| rank[id as usize]).collect();
            assert_eq!(mapped, reference.candidates(&u).unwrap(), "{phase}");
            // top-κ agrees: same items, bit-exact scores
            let a = engine.top_k(&u, 7).unwrap();
            let b = reference.top_k(&u, 7).unwrap();
            assert_eq!(a.len(), b.len(), "{phase}");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(rank[x.id as usize], y.id, "{phase}");
                assert_eq!(x.score, y.score, "{phase}: score drift");
            }
        }
    };

    check(&engine, "before merge");
    engine.merge().unwrap();
    assert_eq!(engine.pending(), 0);
    check(&engine, "after merge");
}

/// Regression (scratch hardening): a coordinator whose worker scratch was
/// warmed on a small catalogue keeps serving correctly after a hot swap
/// to a much larger item matrix.
#[test]
fn worker_scratch_survives_swap_to_larger_catalogue() {
    let k = 8;
    let coord = Coordinator::start(
        serve_cfg(k, 1, Backend::Geomap),
        items(40, k, 7),
        cpu_scorer_factory(),
    )
    .unwrap();
    // warm the worker scratch on the small catalogue
    for s in 0..4u64 {
        let resp = coord.submit(user(k, 500 + s), 5).unwrap();
        assert_eq!(resp.total_items, 40);
    }
    // grow the catalogue 20x and keep serving through the same workers
    let big = items(800, k, 8);
    coord.swap_items(big.clone()).unwrap();
    let mapper = Mapper::from_config(SchemaConfig::TernaryParseTree, k, 0.0);
    let reference = Retriever::build(mapper, big).unwrap();
    for s in 0..10u64 {
        let u = user(k, 600 + s);
        let resp = coord.submit(u.clone(), 5).unwrap();
        assert_eq!(resp.total_items, 800);
        let want = reference.top_k(&u, 5).unwrap();
        assert_eq!(
            resp.results.iter().map(|r| r.id).collect::<Vec<_>>(),
            want.iter().map(|w| w.id).collect::<Vec<_>>()
        );
        assert_eq!(resp.candidates, reference.candidates(&u).unwrap().len());
    }
    coord.shutdown();
}

/// Incremental mutation through the serving facade: upserted items are
/// served with their new factors before any merge; removed ids never
/// appear; an append is immediately retrievable.
#[test]
fn coordinator_serves_mutations_live() {
    let k = 8;
    let coord = Coordinator::start(
        serve_cfg(k, 2, Backend::Geomap),
        items(100, k, 9),
        cpu_scorer_factory(),
    )
    .unwrap();
    // make id 3 the best match for a probe user by construction
    let probe = user(k, 700);
    let mut boosted = probe.clone();
    for v in boosted.iter_mut() {
        *v *= 10.0;
    }
    coord.upsert(3, &boosted).unwrap();
    let resp = coord.submit(probe.clone(), 3).unwrap();
    assert_eq!(resp.results[0].id, 3, "upserted factor must win");
    let exact = dot(&probe, &boosted);
    assert!((resp.results[0].score - exact).abs() < 1e-4);
    // removing it takes it out of every later response
    assert!(coord.remove(3).unwrap().1);
    for _ in 0..5 {
        let resp = coord.submit(probe.clone(), 100).unwrap();
        assert!(resp.results.iter().all(|r| r.id != 3));
    }
    // append at the current edge
    let v = coord.upsert(100, &boosted).unwrap();
    assert!(v > 0);
    let resp = coord.submit(probe, 3).unwrap();
    assert_eq!(resp.total_items, 101);
    assert_eq!(resp.results[0].id, 100, "appended item must be served");
    coord.shutdown();
}

/// Satellite coverage: one `SourceScratch` warmed on the initial
/// catalogue keeps producing correct candidates after upserts grow the
/// id space far past the scratch's initial counter capacity (the
/// `QueryScratch::ensure` growth path), with clean counters across
/// reuse.
#[test]
fn query_scratch_grows_past_initial_capacity_on_upserts() {
    let k = 8;
    let n0 = 16usize;
    let spec = Engine::builder()
        .schema(SchemaConfig::TernaryParseTree)
        .threshold(0.0)
        .mutation(MutationConfig { max_delta: 24 }); // merges fire mid-churn
    let mut engine = spec.build(items(n0, k, 11)).unwrap();
    let mut scratch = SourceScratch::new();
    let mut out = Vec::new();
    // warm the scratch on the small catalogue
    engine
        .candidates_into(&user(k, 800), &mut scratch, &mut out)
        .unwrap();
    // grow 10x past the initial capacity through the append edge,
    // re-querying with the same scratch as the id space expands
    for id in n0 as u32..(10 * n0) as u32 {
        engine.upsert(id, &user(k, 900 + id as u64)).unwrap();
        if id % 13 == 0 {
            engine
                .candidates_into(&user(k, 1000 + id as u64), &mut scratch, &mut out)
                .unwrap();
            assert!(out.iter().all(|&c| c <= id), "candidate beyond edge");
        }
    }
    assert_eq!(engine.len(), 10 * n0);
    // the warmed scratch agrees exactly with a fresh one
    for s in 0..15u64 {
        let u = user(k, 1100 + s);
        engine.candidates_into(&u, &mut scratch, &mut out).unwrap();
        let mut fresh = SourceScratch::new();
        let mut fresh_out = Vec::new();
        engine
            .candidates_into(&u, &mut fresh, &mut fresh_out)
            .unwrap();
        assert_eq!(out, fresh_out, "stale counters after growth");
    }
}

/// The compressed serving tier behind the coordinator: a quantized +
/// packed geomap engine serves through the full batched path, every
/// returned score is still an exact f32 inner product, and mutation
/// semantics (upsert wins, remove disappears) hold end to end.
#[test]
fn quantized_packed_engine_serves_through_coordinator() {
    let k = 16;
    let catalogue = items(300, k, 12);
    let mut cfg = serve_cfg(k, 2, Backend::Geomap);
    cfg.schema = SchemaConfig::TernaryOneHot;
    cfg.quant = QuantMode::Int8 { refine: 4 };
    cfg.postings = PostingsMode::Packed;
    let coord =
        Coordinator::start(cfg, catalogue.clone(), cpu_scorer_factory())
            .unwrap();
    for s in 0..10u64 {
        let u = user(k, 1200 + s);
        let resp = coord.submit(u.clone(), 5).unwrap();
        for w in resp.results.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        for r in &resp.results {
            let exact = dot(&u, catalogue.row(r.id as usize));
            assert!(
                (r.score - exact).abs() < 1e-5,
                "quantized tier must refine to exact scores"
            );
        }
    }
    // mutations flow through both tiers
    let probe = user(k, 1300);
    let mut boosted = probe.clone();
    for v in boosted.iter_mut() {
        *v *= 10.0;
    }
    coord.upsert(7, &boosted).unwrap();
    let resp = coord.submit(probe.clone(), 3).unwrap();
    assert_eq!(resp.results[0].id, 7, "upserted factor must win");
    assert!(coord.remove(7).unwrap().1);
    for _ in 0..5 {
        let resp = coord.submit(probe.clone(), 100).unwrap();
        assert!(resp.results.iter().all(|r| r.id != 7));
    }
    coord.shutdown();
}

/// Quantized recall sanity at the engine level: against the exact f32
/// engine over the same candidates, int8 + refine recovers ≥ 99% of the
/// true top-10 on a gaussian catalogue.
#[test]
fn quantized_recall_stays_within_one_percent() {
    let k = 32;
    let catalogue = items(2000, k, 13);
    let exact = Engine::builder()
        .schema(SchemaConfig::TernaryOneHot)
        .threshold(0.5)
        .build(catalogue.clone())
        .unwrap();
    let quantized = Engine::builder()
        .schema(SchemaConfig::TernaryOneHot)
        .threshold(0.5)
        .quant(QuantMode::Int8 { refine: 4 })
        .build(catalogue)
        .unwrap();
    let (mut hits, mut total) = (0usize, 0usize);
    for s in 0..50u64 {
        let u = user(k, 1400 + s);
        let want: Vec<u32> =
            exact.top_k(&u, 10).unwrap().iter().map(|r| r.id).collect();
        let got: Vec<u32> =
            quantized.top_k(&u, 10).unwrap().iter().map(|r| r.id).collect();
        total += want.len();
        hits += want.iter().filter(|id| got.contains(id)).count();
    }
    let recall = hits as f64 / total as f64;
    assert!(recall >= 0.99, "recall@10 = {recall:.4} (want >= 0.99)");
}
