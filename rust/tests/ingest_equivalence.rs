//! Ingest equivalence (docs/INGEST.md): the online fold-in must be the
//! mathematics it claims and nothing more.
//!
//! Two claims are held:
//!
//! 1. **Solver equivalence** — [`fold_in`]'s factor satisfies the same
//!    ridge normal equations `(XᵀX + λnI) w = Xᵀr` as an independent
//!    dense f64 Gaussian-elimination reference, across random ranks,
//!    observation counts and regularisation strengths — including the
//!    degenerate ends (zero observations, rank-deficient systems).
//! 2. **Serving equivalence** — after an item folds in through the
//!    streaming path (observe → fold → upsert → re-embed → merge), the
//!    coordinator's top-κ responses are byte-identical to a coordinator
//!    *rebuilt from scratch* over the same catalogue with the same
//!    folded factor appended, across posting arenas (raw/packed) ×
//!    quantization (off/int8). Streaming in a factor and having always
//!    had it must be observably the same thing.

use geomap::configx::{Backend, PostingsMode, QuantMode, ServeConfig};
use geomap::coordinator::{Coordinator, Response};
use geomap::ingest::fold_in;
use geomap::linalg::Matrix;
use geomap::runtime::cpu_scorer_factory;
use geomap::testing::{fix, prop};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

/// Dense f64 reference for the fold-in system: assemble
/// `A = XᵀX + λnI`, `b = Xᵀr` and solve by Gaussian elimination with
/// partial pivoting — deliberately nothing like the f32 Cholesky path.
fn reference_solve(k: usize, reg: f32, obs: &[(Vec<f32>, f32)]) -> Vec<f64> {
    let n = obs.len();
    let mut a = vec![vec![0.0f64; k]; k];
    let mut b = vec![0.0f64; k];
    for (x, r) in obs {
        for i in 0..k {
            b[i] += *r as f64 * x[i] as f64;
            for j in 0..k {
                a[i][j] += x[i] as f64 * x[j] as f64;
            }
        }
    }
    let lambda = reg as f64 * n as f64;
    for (i, row) in a.iter_mut().enumerate() {
        row[i] += lambda;
    }
    for col in 0..k {
        let pivot = (col..k)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        a.swap(col, pivot);
        b.swap(col, pivot);
        assert!(a[col][col].abs() > 1e-12, "reference system is singular");
        for row in col + 1..k {
            let m = a[row][col] / a[col][col];
            for c in col..k {
                a[row][c] -= m * a[col][c];
            }
            b[row] -= m * b[col];
        }
    }
    let mut w = vec![0.0f64; k];
    for i in (0..k).rev() {
        let mut s = b[i];
        for j in i + 1..k {
            s -= a[i][j] * w[j];
        }
        w[i] = s / a[i][i];
    }
    w
}

#[test]
fn fold_in_matches_the_dense_reference_across_ranks_and_reg() {
    prop(120, |g| {
        let k = g.usize_in(2..=12);
        let n = g.usize_in(k..=k + 16);
        let reg = g.f32_in(0.02, 0.5);
        let obs: Vec<(Vec<f32>, f32)> = (0..n)
            .map(|_| {
                let x: Vec<f32> = (0..k).map(|_| g.gaussian()).collect();
                (x, g.f32_in(-2.0, 2.0))
            })
            .collect();
        let borrowed: Vec<(&[f32], f32)> =
            obs.iter().map(|(x, r)| (x.as_slice(), *r)).collect();
        let w = fold_in(k, reg, &borrowed).unwrap();
        let w_ref = reference_solve(k, reg, &obs);
        for i in 0..k {
            let tol = 5e-3 * (1.0 + w_ref[i].abs());
            assert!(
                (w[i] as f64 - w_ref[i]).abs() < tol,
                "coord {i}: fold {} vs reference {} (k={k} n={n} reg={reg})",
                w[i],
                w_ref[i]
            );
        }
    });
}

#[test]
fn fold_in_underdetermined_but_regularised_matches_the_reference() {
    // fewer observations than dimensions: XᵀX is rank-deficient, the
    // ridge term alone makes the system definite — both solvers must
    // agree there too, not just on comfortable full-rank inputs
    prop(80, |g| {
        let k = g.usize_in(3..=12);
        let n = g.usize_in(1..=k - 1);
        let reg = g.f32_in(0.05, 0.5);
        let obs: Vec<(Vec<f32>, f32)> = (0..n)
            .map(|_| {
                let x: Vec<f32> = (0..k).map(|_| g.gaussian()).collect();
                (x, g.f32_in(-2.0, 2.0))
            })
            .collect();
        let borrowed: Vec<(&[f32], f32)> =
            obs.iter().map(|(x, r)| (x.as_slice(), *r)).collect();
        let w = fold_in(k, reg, &borrowed).unwrap();
        let w_ref = reference_solve(k, reg, &obs);
        for i in 0..k {
            let tol = 5e-3 * (1.0 + w_ref[i].abs());
            assert!(
                (w[i] as f64 - w_ref[i]).abs() < tol,
                "coord {i}: fold {} vs reference {} (k={k} n={n} reg={reg})",
                w[i],
                w_ref[i]
            );
        }
    });
}

#[test]
fn fold_in_degenerate_ends_hold_their_contracts() {
    // zero observations: the documented inert zero vector, any reg
    for k in [1usize, 4, 9] {
        assert_eq!(fold_in(k, 0.0, &[]).unwrap(), vec![0.0; k]);
        assert_eq!(fold_in(k, 0.3, &[]).unwrap(), vec![0.0; k]);
    }
    // rank-deficient with reg = 0: an error, never an invented factor
    let x = [0.5f32, -1.0, 0.0, 2.0];
    let dup = [(&x[..], 1.0f32), (&x[..], -0.5f32), (&x[..], 2.0f32)];
    assert!(fold_in(4, 0.0, &dup).is_err());
    // the same system under any positive reg solves and matches the
    // reference
    let w = fold_in(4, 0.1, &dup).unwrap();
    let owned: Vec<(Vec<f32>, f32)> =
        dup.iter().map(|&(x, r)| (x.to_vec(), r)).collect();
    let w_ref = reference_solve(4, 0.1, &owned);
    for i in 0..4 {
        assert!((w[i] as f64 - w_ref[i]).abs() < 5e-3);
    }
}

/// Everything in a `Response` except latency and catalogue version (the
/// streamed coordinator took an upsert the rebuilt one never saw, so the
/// version counters legitimately differ; result bytes must not).
fn key(r: &Response) -> (Vec<(u32, u32)>, usize, usize) {
    (
        r.results.iter().map(|s| (s.id, s.score.to_bits())).collect(),
        r.candidates,
        r.total_items,
    )
}

/// The four serving tiers the streamed-vs-rebuilt comparison sweeps.
fn tier_configs(k: usize) -> Vec<(String, ServeConfig)> {
    let mut out = Vec::new();
    for postings in [PostingsMode::Raw, PostingsMode::Packed] {
        for quant in [QuantMode::Off, QuantMode::Int8 { refine: 4 }] {
            let label = format!("{postings:?}/{quant:?}");
            let mut cfg = fix::serve_cfg(k, 2, Backend::Geomap, 0.5);
            cfg.postings = postings;
            cfg.quant = quant;
            // merge every mutation immediately: the comparison judges the
            // *post-merge* index, not the delta overlay
            cfg.mutation.max_delta = 1;
            out.push((label, cfg));
        }
    }
    out
}

#[test]
fn streamed_fold_in_equals_rebuild_from_scratch_across_tiers() {
    let k = 8;
    let n = 160;
    let items = fix::items(n, k, 55);
    // the observe stream: user 9 rates three live items, then the
    // brand-new id `n` — replicated below to precompute the exact factor
    // the ingest thread will fold
    let history: [(u32, f32); 3] = [(3, 1.5), (40, -0.5), (101, 2.0)];
    let new_rating = 1.0f32;

    for (label, cfg) in tier_configs(k) {
        let reg = cfg.ingest.reg;
        let streamed = Coordinator::start(
            cfg.clone(),
            items.clone(),
            cpu_scorer_factory(),
        )
        .unwrap();
        for &(item, rating) in &history {
            assert!(streamed.observe(9, item, rating).unwrap(), "{label}");
        }
        assert!(streamed.observe(9, n as u32, new_rating).unwrap(), "{label}");
        let deadline = Instant::now() + Duration::from_secs(5);
        while streamed.metrics().ingest_item_folds.load(Ordering::Acquire) < 1
        {
            assert!(Instant::now() < deadline, "{label}: item never folded");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(streamed.total_items(), n + 1, "{label}");

        // replicate the fold arithmetic exactly: the user factor from the
        // live co-factors, then the item factor from that user factor
        let resolved: Vec<(&[f32], f32)> = history
            .iter()
            .map(|&(item, rating)| (items.row(item as usize), rating))
            .collect();
        let user_factor = fold_in(k, reg, &resolved).unwrap();
        let folded =
            fold_in(k, reg, &[(user_factor.as_slice(), new_rating)]).unwrap();

        // a coordinator that always had the folded row, built from scratch
        let mut full = Matrix::zeros(n + 1, k);
        for i in 0..n {
            full.row_mut(i).copy_from_slice(items.row(i));
        }
        full.row_mut(n).copy_from_slice(&folded);
        let rebuilt =
            Coordinator::start(cfg.clone(), full, cpu_scorer_factory())
                .unwrap();

        // probes: a random pool plus the folded factor's own direction,
        // which must retrieve the new item identically on both sides
        let mut probes = fix::user_vecs(12, k, 56);
        probes.push(folded.clone());
        for (i, u) in probes.iter().enumerate() {
            let a = streamed.submit(u.clone(), 6).unwrap();
            let b = rebuilt.submit(u.clone(), 6).unwrap();
            assert_eq!(key(&a), key(&b), "{label}: probe {i}");
        }
        let along = streamed.submit(folded.clone(), 6).unwrap();
        assert!(
            along.results.iter().any(|s| s.id == n as u32),
            "{label}: the folded item must be retrievable along its own \
             factor"
        );
        streamed.shutdown();
        rebuilt.shutdown();
    }
}
