//! Freshness soak (docs/INGEST.md): a cached coordinator under a live
//! observe stream with concurrent readers must stay byte-identical to
//! its cache-off twin, keep versions monotone, make every accepted
//! observation visible within the configured SLA, and shut down with
//! exact ingest-counter accounting.
//!
//! Determinism note: the only catalogue mutations here are the ingest
//! thread's own fold-in upserts. Fold results depend solely on the
//! observation prefix processed so far (each absorb + drain is a pure
//! function of ingest state), so two coordinators fed the identical
//! stream converge to bit-identical catalogues regardless of thread
//! timing — which is what lets the twins be compared at all.

use geomap::configx::{Backend, CacheMode, ServeConfig};
use geomap::coordinator::{Coordinator, Response};
use geomap::runtime::cpu_scorer_factory;
use geomap::testing::fix;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

const BASE_ITEMS: usize = 120;
const K: usize = 8;
const STEPS: usize = 200;
const RATERS: u32 = 10;

/// Everything in a `Response` except latency, scores at bit precision.
fn key(r: &Response) -> (Vec<(u32, u32)>, usize, usize, u64) {
    (
        r.results.iter().map(|s| (s.id, s.score.to_bits())).collect(),
        r.candidates,
        r.total_items,
        r.version,
    )
}

fn soak_cfg() -> ServeConfig {
    let mut cfg = fix::serve_cfg(K, 2, Backend::Geomap, 0.0);
    // a queue deep enough that the synchronous test stream never sheds:
    // the accounting checks below demand exactness, not rough counts
    cfg.ingest.queue = 4096;
    cfg
}

/// The deterministic observe stream, sent identically to both twins.
/// Returns (observes sent, new items created).
fn stream(twins: &[&Coordinator]) -> (u64, u64) {
    let mut next_new = BASE_ITEMS as u32;
    let mut sent = 0u64;
    let mut created = 0u64;
    for step in 0..STEPS {
        let user = (step as u32) % RATERS;
        let item = (step * 7 % BASE_ITEMS) as u32;
        let rating = 0.5 + (step % 9) as f32 * 0.5;
        for c in twins {
            assert!(
                c.observe(user, item, rating).unwrap(),
                "deep queue must never shed (step {step})"
            );
        }
        sent += 1;
        if step % 5 == 4 {
            // the same user, having just rated a live item, rates a
            // brand-new contiguous id: an online item fold-in
            for c in twins {
                assert!(c.observe(user, next_new, 1.5).unwrap());
            }
            sent += 1;
            created += 1;
            next_new += 1;
        }
    }
    (sent, created)
}

/// Wait until a coordinator has folded `folds` items and retains no
/// pending observations (ingest fully drained).
fn quiesce(c: &Coordinator, folds: u64, label: &str) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let done = c.metrics().ingest_item_folds.load(Ordering::Acquire)
            >= folds
            && c.ingest_pending() == 0;
        if done {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{label}: ingest never drained ({} folds, {} pending)",
            c.metrics().ingest_item_folds.load(Ordering::Acquire),
            c.ingest_pending()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn cached_twin_stays_byte_identical_under_ingest_churn() {
    let cfg = soak_cfg();
    let off = Coordinator::start(
        cfg.clone(),
        fix::items(BASE_ITEMS, K, 77),
        cpu_scorer_factory(),
    )
    .unwrap();
    let mut cfg_on = cfg;
    cfg_on.cache = CacheMode::Lru { entries: 64 };
    let on = Coordinator::start(
        cfg_on,
        fix::items(BASE_ITEMS, K, 77),
        cpu_scorer_factory(),
    )
    .unwrap();

    let probes = fix::user_vecs(8, K, 78);
    let mut sent_created = (0u64, 0u64);
    // readers hammer both twins while the writer streams: they assert
    // per-coordinator version monotonicity (epoch bumps from fold-in
    // upserts must never be observed out of order) and well-formedness,
    // not cross-twin equality — the twins drain on their own clocks
    std::thread::scope(|scope| {
        for reader in 0..2usize {
            let coords = [&on, &off];
            let probes = &probes;
            scope.spawn(move || {
                let coord = coords[reader % 2];
                let mut last_version = 0u64;
                for round in 0..60 {
                    for u in probes {
                        let r = coord.submit(u.clone(), 5).unwrap();
                        assert!(
                            r.version >= last_version,
                            "reader {reader}: version went backwards \
                             ({} < {last_version}) in round {round}",
                            r.version
                        );
                        last_version = r.version;
                        assert!(r.results.len() <= 5);
                    }
                }
            });
        }
        sent_created = stream(&[&on, &off]);
    });
    let (sent, created) = sent_created;
    quiesce(&on, created, "cache-on");
    quiesce(&off, created, "cache-off");

    // both twins grew the same catalogue and answer byte-identically —
    // a stale cache entry surviving a fold-in epoch bump would break this
    let expected = BASE_ITEMS + created as usize;
    assert_eq!(on.total_items(), expected);
    assert_eq!(off.total_items(), expected);
    for (i, u) in probes.iter().enumerate() {
        // twice on the cached twin: fill, then serve from cache
        let first = on.submit(u.clone(), 5).unwrap();
        let cached = on.submit(u.clone(), 5).unwrap();
        let fresh = off.submit(u.clone(), 5).unwrap();
        assert_eq!(key(&first), key(&fresh), "probe {i}");
        assert_eq!(key(&cached), key(&fresh), "probe {i} (cached)");
    }

    // freshness: every accepted observation that contributed to a fold
    // became visible within the configured SLA, and the counters account
    // for the whole stream exactly
    for (label, c) in [("cache-on", &on), ("cache-off", &off)] {
        let m = c.metrics();
        assert_eq!(
            m.ingest_observed.load(Ordering::Relaxed),
            sent,
            "{label}: every offered observation was accepted"
        );
        assert_eq!(m.ingest_shed.load(Ordering::Relaxed), 0, "{label}");
        assert_eq!(
            m.ingest_item_folds.load(Ordering::Acquire),
            created,
            "{label}: one fold per created item"
        );
        assert_eq!(m.ingest_errors.load(Ordering::Relaxed), 0, "{label}");
        assert_eq!(
            m.ingest_visibility_us.count(),
            created,
            "{label}: one visibility sample per contributing observation"
        );
        assert_eq!(
            m.ingest_sla_breach.load(Ordering::Relaxed),
            0,
            "{label}: all folds inside the {}us SLA",
            soak_cfg().ingest.sla_us
        );
        assert_eq!(c.ingest_pending(), 0, "{label}");
        // the busiest raters see ~40 observations, well under the
        // 64-entry history cap: nothing may have been evicted
        assert_eq!(m.ingest_evicted.load(Ordering::Relaxed), 0, "{label}");
        assert!(
            m.ingest_user_folds.load(Ordering::Relaxed) > 0,
            "{label}: the live-item stream must fold user factors"
        );
    }

    // a cached response from before a fold must never be served after
    // it: force the sequence deterministically
    let probe = fix::user(K, 79);
    let before = on.submit(probe.clone(), 5).unwrap();
    assert!(on.observe(3, expected as u32, 2.0).unwrap());
    quiesce(&on, created + 1, "cache-on (late fold)");
    assert!(off.observe(3, expected as u32, 2.0).unwrap());
    quiesce(&off, created + 1, "cache-off (late fold)");
    let after_on = on.submit(probe.clone(), 5).unwrap();
    let after_off = off.submit(probe, 5).unwrap();
    assert_eq!(after_on.total_items, expected + 1);
    assert_eq!(
        key(&after_on),
        key(&after_off),
        "the post-fold response must reflect the fold, not the cache"
    );
    assert!(after_on.version > before.version, "fold bumps the version");

    // clean shutdown: stop_threads stops ingest first; nothing left to
    // drain, so the counters above are final
    on.shutdown();
    off.shutdown();
}
