//! Scalar ↔ SIMD equivalence properties for the dispatched hot-path
//! kernels (docs/KERNELS.md).
//!
//! Every vector arm must be **bit-identical** to the portable scalar
//! reference on every input shape the serving path can produce:
//!
//! * `dot_i8` — all lengths 0..=257, misaligned slice heads, and the
//!   i8 extremes (±127 from quantization, plus the raw -128 corner);
//! * `accum_lanes` — random chunk sizes 1..=32, duplicate rows, sparse
//!   lane subsets, and saturation pinned exactly at `u16::MAX`;
//! * `unpack_deltas` — every gap bit-width 0..=32 across block
//!   boundaries, including the width-32 near-`u32::MAX` corner.
//!
//! The capstone property re-runs a quantized + packed engine end to end
//! under `kernels: auto` vs `kernels: scalar` and compares served
//! `top_k` ids and raw score bits — the arm must be unobservable.

use geomap::configx::{PostingsMode, QuantMode, SchemaConfig};
use geomap::engine::Engine;
use geomap::kernels::{self, Kernels, KernelsMode};
use geomap::quant::{PackedPostings, BLOCK};
use geomap::rng::Rng;
use geomap::testing::fix;

/// Scalar first, then the host's vector arm when one was detected (the
/// suite still passes — vacuously for the vector cases — on hosts
/// without one; CI's scalar-forced leg covers the fallback arm).
fn arms() -> Vec<&'static Kernels> {
    let mut v = vec![kernels::scalar()];
    if let Some(k) = kernels::vector() {
        v.push(k);
    }
    v
}

#[test]
fn dot_i8_arms_agree_on_every_length_and_offset() {
    let mut rng = Rng::seeded(11);
    let n = 257 + 4;
    let a: Vec<i8> = (0..n).map(|_| rng.next_u64() as i8).collect();
    let b: Vec<i8> = (0..n).map(|_| rng.next_u64() as i8).collect();
    for len in 0..=257usize {
        // misaligned heads: sub-slices starting at every offset 0..4,
        // so the 16-lane vector body sees every alignment class
        for off in 0..4usize {
            let (xa, xb) = (&a[off..off + len], &b[off..off + len]);
            let want = (kernels::scalar().dot_i8)(xa, xb);
            for arm in arms() {
                assert_eq!(
                    (arm.dot_i8)(xa, xb),
                    want,
                    "arm {} len={len} off={off}",
                    arm.name
                );
            }
        }
    }
}

#[test]
fn dot_i8_arms_agree_at_i8_extremes() {
    // quantized codes are clamped to ±127, but the kernel contract is
    // the full i8 domain — pin ±127 and the -128 corner at an odd
    // length so the scalar tail participates too
    let len = 257usize;
    for (va, vb) in [(127i8, 127i8), (-127, 127), (-127, -127), (-128, 127)] {
        let a = vec![va; len];
        let b = vec![vb; len];
        let want = (kernels::scalar().dot_i8)(&a, &b);
        assert_eq!(want, len as i32 * va as i32 * vb as i32);
        for arm in arms() {
            assert_eq!(
                (arm.dot_i8)(&a, &b),
                want,
                "arm {} ({va},{vb})",
                arm.name
            );
        }
    }
}

#[test]
fn accum_lanes_arms_agree_on_random_shapes() {
    let mut rng = Rng::seeded(22);
    for case in 0..60 {
        let chunk = 1 + rng.below(32);
        let groups = 1 + rng.below(64);
        // duplicate rows are legal (several postings of one id in a
        // traversal never happens, but the kernel contract allows it)
        let rows: Vec<u32> = (0..rng.below(200))
            .map(|_| rng.below(groups) as u32)
            .collect();
        let mut lanes: Vec<u16> = (0..chunk as u16).collect();
        rng.shuffle(&mut lanes);
        lanes.truncate(rng.below(chunk + 1));
        let mut inc = vec![0u16; chunk];
        for &l in &lanes {
            inc[l as usize] = 1;
        }
        // seed counters with values across the range, some within one
        // step of saturating, so the saturating add is exercised mid-run
        let base: Vec<u16> = (0..groups * chunk)
            .map(|_| {
                if rng.below(10) == 0 {
                    u16::MAX - rng.below(2) as u16
                } else {
                    (rng.next_u64() % 1000) as u16
                }
            })
            .collect();
        let mut want = base.clone();
        (kernels::scalar().accum_lanes)(&mut want, chunk, &rows, &lanes, &inc);
        for arm in arms().into_iter().skip(1) {
            let mut got = base.clone();
            (arm.accum_lanes)(&mut got, chunk, &rows, &lanes, &inc);
            assert_eq!(
                got, want,
                "arm {} case={case} chunk={chunk} rows={} lanes={}",
                arm.name,
                rows.len(),
                lanes.len()
            );
        }
    }
}

#[test]
fn accum_lanes_saturates_exactly_at_u16_max() {
    // the full-chunk (vectorizable) shape, counters one step from the
    // ceiling: repeated application must clamp at u16::MAX on every arm
    let chunk = 32usize;
    let rows: Vec<u32> = vec![0, 1, 1, 2];
    let lanes: Vec<u16> = (0..chunk as u16).collect();
    let inc = vec![1u16; chunk];
    for arm in arms() {
        let mut counts = vec![u16::MAX - 1; 4 * chunk];
        for _ in 0..3 {
            (arm.accum_lanes)(&mut counts, chunk, &rows, &lanes, &inc);
        }
        // rows 0..=2 hit (row 1 twice per pass): all clamp to MAX
        assert!(
            counts[..3 * chunk].iter().all(|&c| c == u16::MAX),
            "arm {} must clamp at u16::MAX",
            arm.name
        );
        // row 3 never appears: untouched
        assert!(
            counts[3 * chunk..].iter().all(|&c| c == u16::MAX - 1),
            "arm {} touched a row outside `rows`",
            arm.name
        );
    }
}

#[test]
fn unpack_deltas_arms_agree_at_every_bit_width() {
    let mut rng = Rng::seeded(33);
    for width in 0..=32u32 {
        // force the first gap to have exactly `width` bits so the
        // packer picks this width for block 0, and size the list so the
        // cumulative id stays ≤ u32::MAX (width 32's largest decodable
        // gap is u32::MAX - 1: first id 0 → last id u32::MAX)
        let max_gap: u64 = if width == 0 {
            0
        } else {
            ((1u64 << width) - 1).min(u32::MAX as u64 - 1)
        };
        let min_gap: u64 = if width <= 1 { 0 } else { 1u64 << (width - 1) };
        let count = if width == 0 {
            130 // consecutive run crossing a block boundary
        } else {
            ((u32::MAX as u64 - 1) / (max_gap + 1)).clamp(1, 129) as usize + 1
        };
        let mut ids: Vec<u32> = vec![0];
        let mut cur = 0u64;
        for i in 1..count {
            let gap = if width == 0 {
                0
            } else if i == 1 {
                max_gap // pin the block's width on the first gap
            } else {
                min_gap + rng.next_u64() % (max_gap - min_gap + 1)
            };
            cur += gap + 1;
            ids.push(cur as u32);
        }
        assert!(cur <= u32::MAX as u64, "width={width} overflowed the test");
        let pk = PackedPostings::pack(
            1,
            cur as usize + 1,
            |_| ids.as_slice(),
        );
        // the packer chose the width we engineered (first block at
        // least; later blocks may be narrower)
        let (_, _, _, _, block_info, _) = pk.arenas();
        assert_eq!(
            block_info[0] >> 16,
            width,
            "block 0 width for engineered gaps"
        );
        let mut want = Vec::new();
        let mut got = Vec::new();
        let mut off = 0usize;
        for blk in pk.dim_blocks(0) {
            pk.decode_block_with(kernels::scalar(), blk, &mut want);
            assert_eq!(
                want,
                ids[off..off + want.len()],
                "scalar decode disagrees with the source list"
            );
            for arm in arms().into_iter().skip(1) {
                pk.decode_block_with(arm, blk, &mut got);
                assert_eq!(
                    got, want,
                    "arm {} width={width} block={blk}",
                    arm.name
                );
            }
            off += want.len();
        }
        assert_eq!(off, ids.len());
    }
}

#[test]
fn unpack_deltas_width32_near_u32_max() {
    // two-id blocks with a gap of u32::MAX - 1: the widest possible
    // delta, ids at the very top of the id space
    let ids = vec![0u32, u32::MAX];
    let pk = PackedPostings::pack(1, usize::MAX, |_| ids.as_slice());
    let mut out = Vec::new();
    for arm in arms() {
        for blk in pk.dim_blocks(0) {
            pk.decode_block_with(arm, blk, &mut out);
            assert_eq!(out, ids, "arm {}", arm.name);
        }
    }
}

#[test]
fn unpack_deltas_full_random_blocks() {
    // BLOCK-sized random-gap lists across many widths at once; every
    // arm must reproduce the packer's input byte for byte
    let mut rng = Rng::seeded(44);
    for _ in 0..20 {
        let n = 1 + rng.below(3 * BLOCK + 1);
        let mut cur = 0u32;
        let mut ids = Vec::with_capacity(n);
        for i in 0..n {
            cur += u32::from(i > 0) + (rng.next_u64() % (1 << rng.below(16))) as u32;
            ids.push(cur);
        }
        let pk = PackedPostings::pack(1, cur as usize + 1, |_| ids.as_slice());
        let mut want = Vec::new();
        let mut got = Vec::new();
        for blk in pk.dim_blocks(0) {
            pk.decode_block_with(kernels::scalar(), blk, &mut want);
            for arm in arms().into_iter().skip(1) {
                pk.decode_block_with(arm, blk, &mut got);
                assert_eq!(got, want, "arm {} block {blk}", arm.name);
            }
        }
    }
}

#[test]
fn top_k_bytes_identical_across_dispatch_modes() {
    // the whole serving pipeline — packed traversal, i8 scan, exact
    // refine — under auto vs forced-scalar dispatch: ids and raw f32
    // score bits must match exactly. (This test flips the process-wide
    // mode; the other tests in this binary pin arms explicitly, so
    // concurrent execution is safe.)
    let items = fix::items(400, 16, 51);
    let users = fix::users(24, 16, 52);
    let engine = Engine::builder()
        .schema(SchemaConfig::TernaryOneHot)
        .threshold(0.5)
        .quant(QuantMode::Int8 { refine: 4 })
        .postings(PostingsMode::Packed)
        .build(items)
        .unwrap();
    let run = |mode: KernelsMode| -> Vec<(u32, u32)> {
        kernels::set_mode(mode);
        (0..users.rows())
            .flat_map(|u| {
                engine
                    .top_k(users.row(u), 10)
                    .unwrap()
                    .into_iter()
                    .map(|s| (s.id, s.score.to_bits()))
            })
            .collect()
    };
    let auto = run(KernelsMode::Auto);
    let scalar = run(KernelsMode::Scalar);
    kernels::set_mode(KernelsMode::Auto);
    assert_eq!(
        auto, scalar,
        "served top_k depends on the kernel dispatch mode"
    );
}
