//! End-to-end protocol equivalence and robustness (docs/NET.md): a query
//! served over the TCP front-end must be *byte-identical* to the same
//! query through `Coordinator::submit`, malformed lines must cost one
//! error response and never the connection, requests split across
//! arbitrary TCP write boundaries must reassemble, and shutdown must
//! leave no thread behind and no client blocked.

use geomap::configx::Backend;
use geomap::coordinator::{Coordinator, Response};
use geomap::net::{proto, NetClient, NetServer, Request, RequestDecoder};
use geomap::runtime::cpu_scorer_factory;
use geomap::testing::{fix, prop};
use std::io::{Read, Write};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator + front-end on an ephemeral loopback port.
fn start(k: usize, n: usize, seed: u64) -> (Arc<Coordinator>, NetServer) {
    let coord = Arc::new(
        Coordinator::start(
            fix::serve_cfg(k, 2, Backend::Geomap, 0.5),
            fix::items(n, k, seed),
            cpu_scorer_factory(),
        )
        .unwrap(),
    );
    let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
    (coord, server)
}

fn stop(coord: Arc<Coordinator>, server: NetServer) {
    server.shutdown();
    Arc::try_unwrap(coord).ok().map(Coordinator::shutdown);
}

/// Everything in a `Response` except latency, scores at bit precision.
fn key(r: &Response) -> (Vec<(u32, u32)>, usize, usize, u64) {
    (
        r.results.iter().map(|s| (s.id, s.score.to_bits())).collect(),
        r.candidates,
        r.total_items,
        r.version,
    )
}

#[test]
fn tcp_query_is_byte_identical_to_direct_submit() {
    let (coord, server) = start(6, 300, 40);
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    for (i, u) in fix::user_vecs(16, 6, 41).into_iter().enumerate() {
        let via_net = client.query(&u, 5).unwrap();
        let direct = coord.submit(u, 5).unwrap();
        let net_key = (
            via_net
                .results
                .iter()
                .map(|s| (s.id, s.score.to_bits()))
                .collect::<Vec<_>>(),
            via_net.candidates,
            via_net.total_items,
            via_net.version,
        );
        assert_eq!(net_key, key(&direct), "user {i} diverged over the wire");
    }
    drop(client);
    stop(coord, server);
}

#[test]
fn malformed_lines_error_without_killing_the_connection() {
    let (coord, server) = start(4, 100, 50);
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let good = fix::user(4, 51);
    let bad: &[&[u8]] = &[
        br#"{"user":[0.1,0.2"#,
        br#"{"user":[NaN],"kappa":1}"#,
        br#"{"user":[1e999],"kappa":1}"#,
        br#"{"user":[01],"kappa":1}"#,
        br#"{"user":[[1,2]],"kappa":1}"#,
        br#"{"user":[1],"kappa":0}"#,
        br#"{"user":[1],"kappa":99999999}"#,
        br#"{"kappa":5}"#,
        br#"{"upsert":5}"#,
        br#"{"remove":1,"kappa":2}"#,
        br#"not json"#,
        br#"{"user":[1],"kappa":2}trailing"#,
    ];
    let before = coord
        .metrics()
        .net_decode_errors
        .load(Ordering::Relaxed);
    for line in bad {
        let resp = client.send_raw(line).unwrap();
        assert!(
            resp.starts_with(b"{\"error\":"),
            "{} must draw an error response, got {}",
            String::from_utf8_lossy(line),
            String::from_utf8_lossy(&resp)
        );
        // the same connection still serves the next well-formed query
        let ok = client.query(&good, 3).unwrap();
        assert!(ok.results.len() <= 3);
    }
    let after = coord.metrics().net_decode_errors.load(Ordering::Relaxed);
    assert_eq!(after - before, bad.len() as u64);
    drop(client);
    stop(coord, server);
}

#[test]
fn requests_split_across_tcp_writes_reassemble() {
    let (coord, server) = start(4, 100, 60);
    let mut stream = std::net::TcpStream::connect(server.local_addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut wire = Vec::new();
    proto::encode_query(&mut wire, &fix::user(4, 61), 3);
    // drip the request a few bytes per segment; the decoder must buffer
    // partial lines across reads
    for chunk in wire.chunks(3) {
        stream.write_all(chunk).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut buf = Vec::new();
    let mut chunk = [0u8; 256];
    while !buf.contains(&b'\n') {
        let n = stream.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed the connection mid-response");
        buf.extend_from_slice(&chunk[..n]);
    }
    assert!(
        buf.starts_with(b"{\"results\":"),
        "unexpected response: {}",
        String::from_utf8_lossy(&buf)
    );
    drop(stream);
    stop(coord, server);
}

#[test]
fn mutations_flow_through_the_socket() {
    let k = 4;
    let (coord, server) = start(k, 64, 70);
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let v0 = coord.submit(fix::user(k, 71), 3).unwrap().version;

    // upsert advances the version and changes subsequent responses
    let v1 = client.upsert(7, &vec![2.0; k]).unwrap();
    assert!(v1 > v0);
    // remove reports whether the id was live
    let (v2, live) = client.remove(7).unwrap();
    assert!(v2 > v1);
    assert!(live);
    let (_, live_again) = client.remove(7).unwrap();
    assert!(!live_again, "second remove of the same id must report dead");

    // wrong-dimension upsert: decodes fine, rejected by the coordinator —
    // an error response plus one `net_malformed`, not a decode error
    let malformed_before =
        coord.metrics().net_malformed.load(Ordering::Relaxed);
    let err = client.upsert(3, &vec![1.0; k + 1]).unwrap_err();
    assert!(err.to_string().contains("server error"));
    assert_eq!(
        coord.metrics().net_malformed.load(Ordering::Relaxed),
        malformed_before + 1
    );
    assert_eq!(
        coord.metrics().net_decode_errors.load(Ordering::Relaxed),
        0,
        "a well-formed but invalid request is not a decode error"
    );

    // connection still lives
    assert!(client.query(&fix::user(k, 72), 3).unwrap().results.len() <= 3);
    drop(client);
    stop(coord, server);
}

#[test]
fn non_finite_upsert_factors_rejected_at_the_wire() {
    // Regression (ISSUE 9 satellite): a non-finite factor lane must
    // never reach the engine through the TCP path. JSON cannot spell
    // NaN/Inf literally (that's a parse error), but `1e39` is a valid
    // JSON number that overflows f32 to +Inf — the decoder rejects it
    // at `f32_array`, so it costs one *decode* error and the catalogue
    // never sees the row.
    let k = 4;
    let (coord, server) = start(k, 64, 75);
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let decode_before =
        coord.metrics().net_decode_errors.load(Ordering::Relaxed);
    let v0 = client.query(&fix::user(k, 76), 3).unwrap().version;
    let bad: &[&[u8]] = &[
        br#"{"upsert":3,"factor":[1e39,0,0,0]}"#,
        br#"{"upsert":3,"factor":[0,-1e39,0,0]}"#,
        br#"{"upsert":3,"factor":[0,0,NaN,0]}"#,
    ];
    for line in bad {
        let resp = client.send_raw(line).unwrap();
        assert!(
            resp.starts_with(b"{\"error\":"),
            "{} must be rejected, got {}",
            String::from_utf8_lossy(line),
            String::from_utf8_lossy(&resp)
        );
    }
    assert_eq!(
        coord.metrics().net_decode_errors.load(Ordering::Relaxed),
        decode_before + bad.len() as u64,
        "non-finite factors are decode errors, not engine errors"
    );
    // the rejected upserts never mutated the catalogue: the version is
    // unchanged and a live query still serves
    let r = client.query(&fix::user(k, 76), 3).unwrap();
    assert_eq!(r.version, v0, "rejected upserts must not bump the version");
    drop(client);
    stop(coord, server);
}

#[test]
fn decoded_requests_serve_byte_identically_to_originals() {
    let k = 6;
    let (coord, server) = start(k, 200, 80);
    let client = std::cell::RefCell::new(
        NetClient::connect(server.local_addr()).unwrap(),
    );
    prop(48, |g| {
        let user: Vec<f32> = (0..k).map(|_| g.gaussian()).collect();
        let kappa = g.usize_in(1..=16);

        // encode → streaming decode is bit-exact
        let mut wire = Vec::new();
        proto::encode_query(&mut wire, &user, kappa);
        let mut dec = RequestDecoder::new();
        dec.feed(&wire);
        let decoded: Vec<f32> = match dec.next_request() {
            Some(Ok(Request::Query { user: u, kappa: kq })) => {
                assert_eq!(kq, kappa);
                u.to_vec()
            }
            other => panic!("round-trip failed to decode: {other:?}"),
        };
        assert!(dec.next_request().is_none(), "one line, one request");
        assert_eq!(
            decoded.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            user.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "factor bits changed across encode → decode"
        );

        // serving the decoded factor equals serving the original
        let a = coord.submit(decoded, kappa).unwrap();
        let b = coord.submit(user.clone(), kappa).unwrap();
        assert_eq!(key(&a), key(&b));

        // a subset goes through the real socket as well
        if g.bool_with(0.25) {
            let via_net = client.borrow_mut().query(&user, kappa).unwrap();
            assert_eq!(
                via_net
                    .results
                    .iter()
                    .map(|s| (s.id, s.score.to_bits()))
                    .collect::<Vec<_>>(),
                b.results
                    .iter()
                    .map(|s| (s.id, s.score.to_bits()))
                    .collect::<Vec<_>>()
            );
        }
    });
    drop(client);
    stop(coord, server);
}

#[test]
fn metrics_account_for_connections_and_bytes() {
    let (coord, server) = start(4, 64, 90);
    let m = coord.metrics();
    let u = fix::user(4, 91);
    {
        let mut a = NetClient::connect(server.local_addr()).unwrap();
        let mut b = NetClient::connect(server.local_addr()).unwrap();
        a.query(&u, 2).unwrap();
        b.query(&u, 2).unwrap();
        assert_eq!(m.net_connections.load(Ordering::Relaxed), 2);
        assert!(m.net_bytes_in.load(Ordering::Relaxed) > 0);
        assert!(m.net_bytes_out.load(Ordering::Relaxed) > 0);
    }
    // client drop closes the sockets; the server threads notice and
    // count the close shortly after
    let deadline = Instant::now() + Duration::from_secs(5);
    while m.net_closed.load(Ordering::Relaxed) < 2 {
        assert!(Instant::now() < deadline, "connection closes never counted");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(m.report().contains("net:"), "report must show the net line");
    stop(coord, server);
}

#[test]
fn stats_verb_round_trips_a_populated_snapshot() {
    let k = 4;
    let (coord, server) = start(k, 128, 97);
    let mut client = NetClient::connect(server.local_addr()).unwrap();

    // mixed burst: queries exercise candgen/rescore, mutations exercise
    // the ack path, everything exercises net decode/encode
    for i in 0..12u32 {
        client.query(&fix::user(k, 98 + u64::from(i)), 3).unwrap();
        if i % 4 == 0 {
            client.upsert(200 + i, &vec![0.5; k]).unwrap();
            client.remove(200 + i).unwrap();
        }
    }

    let j = client.stats().unwrap();
    let req = j.get("requests").unwrap();
    assert_eq!(req.get("completed").unwrap().as_usize().unwrap(), 12);
    assert!(req.get("batches").unwrap().as_usize().unwrap() >= 1);

    // every serving stage must have recorded spans after the burst
    let stages = j.get("stages").unwrap();
    for stage in ["candgen_us", "rescore_us", "net_decode_us", "net_encode_us"]
    {
        let count = stages
            .get(stage)
            .unwrap()
            .get("count")
            .unwrap()
            .as_usize()
            .unwrap();
        assert!(count > 0, "stage {stage} recorded nothing");
    }
    assert!(
        j.get("latency_us")
            .unwrap()
            .get("count")
            .unwrap()
            .as_usize()
            .unwrap()
            >= 12
    );
    let work = j.get("work").unwrap();
    assert!(
        work.get("posting_lists").unwrap().as_usize().unwrap() > 0,
        "index traversal must tally posting lists"
    );
    assert!(
        work.get("refines_f32").unwrap().as_usize().unwrap() > 0,
        "rescore must tally f32 refinements"
    );
    // slow log is an array (default 10ms threshold: usually empty here)
    let _ = j.get("slow").unwrap().as_arr().unwrap();

    // raw adversarial forms of the stats verb
    let resp = client.send_raw(br#"{"stats":true}"#).unwrap();
    assert!(
        resp.starts_with(b"{\"requests\":"),
        "stats response must open with the requests section: {}",
        String::from_utf8_lossy(&resp)
    );
    for bad in
        [&br#"{"stats":false}"#[..], br#"{"stats":true,"kappa":1}"#]
    {
        let resp = client.send_raw(bad).unwrap();
        assert!(
            resp.starts_with(b"{\"error\":"),
            "{} must be rejected",
            String::from_utf8_lossy(bad)
        );
    }

    // the stats round trip itself does not inflate request counters
    let j2 = client.stats().unwrap();
    assert_eq!(
        j2.get("requests")
            .unwrap()
            .get("completed")
            .unwrap()
            .as_usize()
            .unwrap(),
        12,
        "stats must not count as a served query"
    );
    drop(client);
    stop(coord, server);
}

#[test]
fn shutdown_disconnects_idle_clients() {
    let (coord, server) = start(4, 64, 95);
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let u = fix::user(4, 96);
    client.query(&u, 2).unwrap();
    // the client is idle (its server thread blocked in read); shutdown
    // must half-close that socket, join the thread, and the next client
    // round trip must fail rather than hang
    server.shutdown();
    assert!(client.query(&u, 2).is_err());
    Arc::try_unwrap(coord).ok().map(Coordinator::shutdown);
}
