//! Whole-pipeline integration tests: data → (mf) → map → index →
//! retrieve → evaluate, plus reproducibility and the paper's qualitative
//! claims at test scale.

use geomap::configx::SchemaConfig;
use geomap::data::{gaussian_factors, MovieLensSynth};
use geomap::embedding::{Mapper, PermutationKind, TessellationKind};
use geomap::evalx::{accuracy_sparsity_sweep, Comparison};
use geomap::mf::AlsTrainer;
use geomap::retrieval::{RecoveryReport, Retriever};
use geomap::rng::Rng;
use geomap::tessellation::{brute_force_assign, Tessellation, TernaryTessellation};

/// Same seed → bit-identical evaluation report.
#[test]
fn full_pipeline_is_deterministic() {
    let run = || {
        // shared fixture draw (stream-identical to the historical
        // two-call gaussian_factors sequence from one seeded rng)
        let (users, items) = geomap::testing::fix::workload(24, 160, 8, 77);
        let results = Comparison::default().run(&users, &items).unwrap();
        results
            .iter()
            .map(|r| {
                (
                    r.label.clone(),
                    r.report.mean_discarded(),
                    r.report.mean_accuracy(),
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

/// The geometric core property at pipeline level: items angularly close
/// to the user are far more likely to survive pruning than far items.
#[test]
fn pruning_is_geometry_aware() {
    let k = 16;
    let mut rng = Rng::seeded(3);
    let items = gaussian_factors(&mut rng, 800, k);
    let mapper = Mapper::from_config(SchemaConfig::TernaryParseTree, k, 1.0);
    let retriever = Retriever::build(mapper, items).unwrap();

    let mut near_survive = 0usize;
    let mut far_survive = 0usize;
    let mut near_total = 0usize;
    let mut far_total = 0usize;
    for _ in 0..40 {
        let user: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
        let cands = retriever.candidates(&user).unwrap();
        let mut is_cand = vec![false; retriever.items()];
        for &c in &cands {
            is_cand[c as usize] = true;
        }
        // rank items by angular distance; compare survival in the top and
        // bottom deciles
        let mut by_dist: Vec<(usize, f32)> = (0..retriever.items())
            .map(|i| {
                (
                    i,
                    geomap::geometry::angular_distance(
                        &user,
                        retriever.item_factors().row(i),
                    ),
                )
            })
            .collect();
        by_dist.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let decile = retriever.items() / 10;
        for &(i, _) in &by_dist[..decile] {
            near_total += 1;
            near_survive += is_cand[i] as usize;
        }
        for &(i, _) in &by_dist[by_dist.len() - decile..] {
            far_total += 1;
            far_survive += is_cand[i] as usize;
        }
    }
    let near_rate = near_survive as f64 / near_total as f64;
    let far_rate = far_survive as f64 / far_total as f64;
    assert!(
        near_rate > 2.0 * far_rate,
        "near {near_rate:.3} vs far {far_rate:.3}"
    );
}

/// Rust Algorithm 2 equals exhaustive search over Γ for small k — the
/// paper's Lemma 1 at integration level (module test covers unit level).
#[test]
fn ternary_assignment_is_exact_lemma1() {
    let mut rng = Rng::seeded(5);
    for k in [2usize, 3, 4, 5, 6] {
        let tess = TernaryTessellation::new(k);
        for _ in 0..50 {
            let z: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
            let fast = tess.assign(&z);
            let brute = brute_force_assign(&z, 1);
            // compare achieved cosine, not the raw levels (ties can pick
            // different but equally good vectors)
            let cos = |t: &geomap::tessellation::TessVector| {
                let a = t.to_unit();
                let num: f32 = a.iter().zip(&z).map(|(x, y)| x * y).sum();
                let nz: f32 = z.iter().map(|v| v * v).sum::<f32>().sqrt();
                num / nz
            };
            assert!(
                (cos(&fast) - cos(&brute)).abs() < 1e-5,
                "k={k} z={z:?}: fast {} vs brute {}",
                cos(&fast),
                cos(&brute)
            );
        }
    }
}

/// MF factors flow through the sparse map end to end: the learned-factor
/// retrieval keeps meaningful accuracy at meaningful discard.
#[test]
fn learned_factors_pipeline_end_to_end() {
    let synth = MovieLensSynth {
        n_users: 80,
        n_items: 200,
        n_ratings: 5_000,
        ..MovieLensSynth::small()
    };
    let mut rng = Rng::seeded(11);
    let ratings = synth.generate(&mut rng);
    let model = AlsTrainer { k: 8, ..Default::default() }
        .train(&ratings, 5, 11)
        .unwrap();

    let mapper = Mapper::from_config(SchemaConfig::TernaryParseTree, 8, 1.2);
    let retriever = Retriever::build(mapper, model.item_factors.clone()).unwrap();
    let users = model.user_factors.slice_rows(0, 40);
    let report = RecoveryReport::evaluate(
        &users,
        &model.item_factors,
        10,
        |_, u| retriever.candidates(u).unwrap(),
    );
    assert!(
        report.mean_discarded() > 0.3,
        "discard {}",
        report.mean_discarded()
    );
    assert!(
        report.mean_accuracy() > 0.6,
        "accuracy {}",
        report.mean_accuracy()
    );
}

/// Fig-5 shape: discard grows monotonically with the threshold while
/// accuracy falls monotonically (within noise).
#[test]
fn sweep_tradeoff_shape() {
    let (users, items) = geomap::testing::fix::workload(32, 400, 16, 13);
    let pts = accuracy_sparsity_sweep(
        SchemaConfig::TernaryParseTree,
        &users,
        &items,
        5,
        &[0.0, 0.6, 1.0, 1.4, 1.8],
    )
    .unwrap();
    for w in pts.windows(2) {
        assert!(w[1].mean_discarded >= w[0].mean_discarded - 1e-9);
        assert!(w[1].mean_accuracy <= w[0].mean_accuracy + 0.02);
    }
    assert!(pts[0].mean_accuracy > 0.99, "no thresholding → near-perfect");
}

/// One-hot and parse-tree maps agree on the retrieval *semantics* even
/// though their index spaces differ: same tessellation → overlapping
/// supports behave equivalently for same-region queries.
#[test]
fn schemas_agree_for_identical_factors() {
    let k = 12;
    let mut rng = Rng::seeded(17);
    let z: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
    for kind in [PermutationKind::OneHot, PermutationKind::ParseTree] {
        let mapper = Mapper::new(TessellationKind::Ternary, kind, k);
        let a = mapper.map(&z).unwrap();
        let b = mapper.map(&z).unwrap();
        assert_eq!(a.indices(), b.indices());
        assert_eq!(a.nnz(), z.iter().filter(|v| **v != 0.0).count());
    }
}
