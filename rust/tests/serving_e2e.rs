//! End-to-end coordinator tests: correctness under concurrency, overload
//! shedding, hot swaps under load, and the XLA-vs-CPU scorer equivalence
//! through the full serving path.

use geomap::configx::{Backend, SchemaConfig, ServeConfig};
use geomap::coordinator::Coordinator;
use geomap::embedding::Mapper;
use geomap::retrieval::Retriever;
use geomap::rng::Rng;
use geomap::runtime::{cpu_scorer_factory, xla_scorer_factory};
use geomap::testing::fix::items;
use std::sync::Arc;

fn cfg(k: usize, shards: usize, threshold: f32) -> ServeConfig {
    geomap::testing::fix::serve_cfg(k, shards, Backend::Geomap, threshold)
}

/// The coordinator (batched, sharded) must return exactly what the
/// single-threaded Retriever returns for every query.
#[test]
fn coordinator_equals_single_thread_retriever() {
    let k = 16;
    let catalogue = items(500, k, 1);
    let threshold = 1.0;
    let coord = Coordinator::start(
        cfg(k, 3, threshold),
        catalogue.clone(),
        cpu_scorer_factory(),
    )
    .unwrap();
    let mapper = Mapper::from_config(SchemaConfig::TernaryParseTree, k, threshold);
    let reference = Retriever::build(mapper, catalogue).unwrap();

    let mut rng = Rng::seeded(2);
    for _ in 0..25 {
        let user: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
        let got = coord.submit(user.clone(), 10).unwrap();
        let want = reference.top_k(&user, 10).unwrap();
        assert_eq!(
            got.results.iter().map(|s| s.id).collect::<Vec<_>>(),
            want.iter().map(|s| s.id).collect::<Vec<_>>(),
        );
        for (g, w) in got.results.iter().zip(&want) {
            assert!((g.score - w.score).abs() < 1e-4);
        }
        let want_cands = reference.candidates(&user).unwrap().len();
        assert_eq!(got.candidates, want_cands);
    }
    coord.shutdown();
}

/// Same check through the PJRT scorer (skipped without artifacts).
#[test]
fn coordinator_with_xla_scorer_equals_reference() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let k = 16;
    let catalogue = items(600, k, 3);
    let threshold = 1.0;
    let mut c = cfg(k, 2, threshold);
    c.use_xla = true;
    let coord = Coordinator::start(
        c,
        catalogue.clone(),
        xla_scorer_factory("artifacts"),
    )
    .unwrap();
    let mapper = Mapper::from_config(SchemaConfig::TernaryParseTree, k, threshold);
    let reference = Retriever::build(mapper, catalogue).unwrap();
    let mut rng = Rng::seeded(4);
    for _ in 0..10 {
        let user: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
        let got = coord.submit(user.clone(), 10).unwrap();
        let want = reference.top_k(&user, 10).unwrap();
        assert_eq!(got.results.len(), want.len());
        for (g, w) in got.results.iter().zip(&want) {
            assert!(
                (g.score - w.score).abs() < 1e-3,
                "{} vs {}",
                g.score,
                w.score
            );
        }
    }
    coord.shutdown();
}

/// Overload: a tiny queue must shed rather than block forever; accepted
/// requests still complete.
#[test]
fn overload_sheds_and_recovers() {
    let k = 8;
    let mut c = cfg(k, 1, 0.0);
    c.queue_cap = 16;
    c.max_batch = 4;
    let coord =
        Arc::new(Coordinator::start(c, items(2000, k, 5), cpu_scorer_factory()).unwrap());
    let mut handles = Vec::new();
    for t in 0..32 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seeded(50 + t);
            let mut ok = 0;
            let mut shed = 0;
            for _ in 0..20 {
                let u: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
                match coord.submit(u, 5) {
                    Ok(_) => ok += 1,
                    Err(_) => shed += 1,
                }
            }
            (ok, shed)
        }));
    }
    let (mut total_ok, mut total_shed) = (0, 0);
    for h in handles {
        let (ok, shed) = h.join().unwrap();
        total_ok += ok;
        total_shed += shed;
    }
    assert_eq!(total_ok + total_shed, 32 * 20);
    assert!(total_ok > 0, "some requests must get through");
    // after the burst the system still serves
    let mut rng = Rng::seeded(99);
    let u: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
    assert!(Arc::clone(&coord).submit(u, 5).is_ok());
    let m = coord.metrics();
    assert_eq!(
        m.accepted.load(std::sync::atomic::Ordering::Relaxed) as usize,
        total_ok + 1
    );
    Arc::try_unwrap(coord).ok().map(Coordinator::shutdown);
}

/// Hot swap while clients hammer the coordinator: every response must be
/// internally consistent with *some* catalogue version.
#[test]
fn hot_swap_under_load_is_consistent() {
    let k = 8;
    let coord = Arc::new(
        Coordinator::start(cfg(k, 2, 0.0), items(300, k, 6), cpu_scorer_factory())
            .unwrap(),
    );
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let swapper = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut seed = 7;
            let mut sizes = Vec::new();
            while !stop.load(std::sync::atomic::Ordering::Acquire) {
                seed += 1;
                let n = 200 + (seed as usize % 3) * 100;
                sizes.push(n);
                coord.swap_items(items(n, k, seed)).unwrap();
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            sizes
        })
    };
    let mut rng = Rng::seeded(8);
    for _ in 0..200 {
        let u: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
        let resp = Arc::clone(&coord).submit(u, 5).unwrap();
        // consistency: candidate count within the response's own catalogue
        assert!(resp.candidates <= resp.total_items);
        assert!([200, 300, 400].contains(&resp.total_items));
        for s in &resp.results {
            assert!((s.id as usize) < resp.total_items);
        }
    }
    stop.store(true, std::sync::atomic::Ordering::Release);
    let sizes = swapper.join().unwrap();
    assert!(!sizes.is_empty(), "swapper must have swapped at least once");
    Arc::try_unwrap(coord).ok().map(Coordinator::shutdown);
}

/// Mixed kappas within one batch are honoured per request.
#[test]
fn per_request_kappa_is_respected() {
    let k = 8;
    let coord = Arc::new(
        Coordinator::start(cfg(k, 1, 0.0), items(400, k, 9), cpu_scorer_factory())
            .unwrap(),
    );
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seeded(60 + t);
            let kappa = 1 + (t as usize % 7);
            let u: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
            let resp = coord.submit(u, kappa).unwrap();
            assert!(resp.results.len() <= kappa, "kappa {kappa}");
            (kappa, resp.results.len())
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    Arc::try_unwrap(coord).ok().map(Coordinator::shutdown);
}

/// Failure injection: a backend whose construction fails must surface a
/// clean per-request error (no hang, no panic), and the coordinator must
/// still shut down.
#[test]
fn broken_scorer_factory_fails_requests_cleanly() {
    use geomap::error::GeomapError;
    use geomap::runtime::ScorerFactory;
    let factory: ScorerFactory = Arc::new(|| {
        Err(GeomapError::Xla("injected: backend unavailable".into()))
    });
    let k = 8;
    let coord = Coordinator::start(cfg(k, 2, 0.0), items(50, k, 20), factory)
        .unwrap();
    let mut rng = Rng::seeded(21);
    for _ in 0..5 {
        let u: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
        let err = coord.submit(u, 3).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("backend unavailable"), "{msg}");
    }
    coord.shutdown();
}

/// Failure injection: a backend that errors on *every call* after
/// construction also fails requests cleanly.
#[test]
fn scorer_runtime_errors_propagate() {
    use geomap::error::{GeomapError, Result as GResult};
    use geomap::linalg::Matrix as M;
    use geomap::runtime::{Scorer, ScorerFactory, TopkResult};

    struct Exploding;
    impl Scorer for Exploding {
        fn score(&self, _u: &M, _v: &M) -> GResult<M> {
            Err(GeomapError::Xla("injected: score failed".into()))
        }
        fn score_topk(&self, _u: &M, _v: &M, _k: usize) -> GResult<TopkResult> {
            Err(GeomapError::Xla("injected: score failed".into()))
        }
        fn label(&self) -> String {
            "exploding".into()
        }
    }
    let factory: ScorerFactory = Arc::new(|| Ok(Box::new(Exploding)));
    let k = 8;
    // threshold 0 guarantees non-empty candidates, forcing a score call
    let coord =
        Coordinator::start(cfg(k, 1, 0.0), items(100, k, 22), factory).unwrap();
    let mut rng = Rng::seeded(23);
    let u: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
    let err = coord.submit(u, 3).unwrap_err();
    assert!(err.to_string().contains("score failed"), "{err}");
    coord.shutdown();
}

/// Shutdown with requests still queued: pending clients get errors, not
/// hangs.
#[test]
fn shutdown_drains_without_hanging() {
    let k = 8;
    let mut c = cfg(k, 1, 0.0);
    c.max_wait_us = 50_000; // slow batcher so requests queue up
    c.max_batch = 64;
    let coord = Arc::new(
        Coordinator::start(c, items(100, k, 24), cpu_scorer_factory()).unwrap(),
    );
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seeded(70 + t);
            let u: Vec<f32> = (0..k).map(|_| rng.gaussian_f32()).collect();
            // either a normal response (drained) or a clean rejection
            let _ = coord.submit(u, 3);
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(5));
    // drop our handle concurrently with in-flight submits
    drop(Arc::try_unwrap(coord).map(Coordinator::shutdown));
    for h in handles {
        h.join().unwrap(); // must terminate
    }
}
