//! End-to-end snapshot coverage (ISSUE 2 acceptance):
//!
//! * build → save → load → *exact* top-k equivalence on every backend;
//! * mutate (upsert / remove / merge) → save → load equivalence, and
//!   mutability surviving the round trip;
//! * corrupted / truncated / version-bumped files rejected loudly;
//! * explicit builder overrides conflict by error, never silently;
//! * coordinator warm start + background checkpointing.

use geomap::configx::{Backend, CheckpointConfig, MutationConfig, SchemaConfig, ServeConfig};
use geomap::coordinator::Coordinator;
use geomap::engine::Engine;
use geomap::runtime::cpu_scorer_factory;
use geomap::snapshot;
use geomap::testing::fix::{items, user_vecs as users};

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir()
        .join("geomap-snapshot-e2e")
        .join(format!("p{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_string_lossy().into_owned()
}

/// Exact equality of candidates and scored top-k between two engines.
fn assert_identical(a: &Engine, b: &Engine, k: usize, seed: u64) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.dim(), b.dim());
    assert_eq!(a.label(), b.label());
    for u in users(12, k, seed) {
        assert_eq!(
            a.candidates(&u).unwrap(),
            b.candidates(&u).unwrap(),
            "candidate sets diverged"
        );
        let (ta, tb) = (a.top_k(&u, 10).unwrap(), b.top_k(&u, 10).unwrap());
        assert_eq!(ta.len(), tb.len());
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(x.id, y.id, "top-k ids diverged");
            assert_eq!(
                x.score.to_bits(),
                y.score.to_bits(),
                "top-k scores are not byte-exact"
            );
        }
    }
}

#[test]
fn every_backend_roundtrips_byte_exact() {
    let k = 8;
    let its = items(180, k, 1);
    for backend in [
        Backend::Geomap,
        Backend::Srp { bits: 3, tables: 2 },
        Backend::Superbit { bits: 3, depth: 3, tables: 2 },
        Backend::Cros { m: 12, l: 1, tables: 2 },
        Backend::PcaTree { leaf_frac: 0.25 },
        Backend::Brute,
    ] {
        let built = Engine::builder()
            .backend(backend)
            .threshold(0.5)
            .seed(0xBEEF)
            .build(its.clone())
            .unwrap();
        let path = tmp(&format!("backend-{}.gsnp", backend.name()));
        built.save_snapshot(&path).unwrap();
        let loaded = Engine::builder().from_snapshot(&path).unwrap();
        assert_eq!(loaded.backend(), backend);
        assert!(loaded.spec().same_spec(&built.spec()));
        assert_identical(&built, &loaded, k, 100);
    }
}

#[test]
fn mutated_engine_roundtrips_and_stays_mutable() {
    let k = 8;
    let mut built = Engine::builder()
        .threshold(0.4)
        .mutation(MutationConfig { max_delta: 0 }) // manual merges only
        .build(items(90, k, 2))
        .unwrap();
    // upsert-replace, append, remove — all pending in the delta
    let f1 = users(1, k, 3).pop().unwrap();
    let f2 = users(1, k, 4).pop().unwrap();
    built.upsert(17, &f1).unwrap();
    built.upsert(90, &f2).unwrap();
    built.remove(33).unwrap();
    assert!(built.pending() > 0);

    let path = tmp("mutated.gsnp");
    built.save_snapshot(&path).unwrap();
    let mut loaded = Engine::builder().from_snapshot(&path).unwrap();
    let stats = loaded.stats();
    assert_eq!(stats.live, built.stats().live);
    assert_eq!(stats.pending, built.stats().pending);
    assert_eq!(stats.tombstones, built.stats().tombstones);
    assert_eq!(loaded.factor(17).unwrap(), &f1[..]);
    assert_eq!(loaded.factor(90).unwrap(), &f2[..]);
    assert_eq!(loaded.factor(33), None);
    assert_identical(&built, &loaded, k, 200);

    // merging both gives identical results again
    built.merge().unwrap();
    loaded.merge().unwrap();
    assert_eq!(loaded.pending(), 0);
    assert_identical(&built, &loaded, k, 300);

    // post-merge snapshot (holes in the id space) also round-trips
    let path2 = tmp("merged.gsnp");
    built.save_snapshot(&path2).unwrap();
    let mut reloaded = Engine::builder().from_snapshot(&path2).unwrap();
    assert_identical(&built, &reloaded, k, 400);
    // and the loaded engine keeps accepting mutations
    let f3 = users(1, k, 5).pop().unwrap();
    reloaded.upsert(33, &f3).unwrap();
    assert_eq!(reloaded.factor(33).unwrap(), &f3[..]);
}

#[test]
fn explicit_builder_overrides_conflict_by_error() {
    let k = 6;
    let engine = Engine::builder()
        .schema(SchemaConfig::TernaryParseTree)
        .threshold(1.25)
        .build(items(40, k, 6))
        .unwrap();
    let path = tmp("conflict.gsnp");
    engine.save_snapshot(&path).unwrap();

    // untouched defaults: the snapshot config simply applies
    let loaded = Engine::builder().from_snapshot(&path).unwrap();
    assert!(loaded.spec().same_spec(&engine.spec()));

    // matching explicit settings are fine
    assert!(Engine::builder()
        .threshold(1.25)
        .schema(SchemaConfig::TernaryParseTree)
        .from_snapshot(&path)
        .is_ok());

    // conflicting explicit settings fail loudly instead of silently
    // winning or losing
    let err = Engine::builder()
        .threshold(0.7)
        .from_snapshot(&path)
        .unwrap_err()
        .to_string();
    assert!(err.contains("conflicts"), "{err}");
    assert!(err.contains("threshold"), "{err}");
    let err = Engine::builder()
        .backend(Backend::Brute)
        .from_snapshot(&path)
        .unwrap_err()
        .to_string();
    assert!(err.contains("backend"), "{err}");
}

#[test]
fn damaged_files_are_rejected() {
    let engine = Engine::builder().build(items(50, 6, 7)).unwrap();
    let path = tmp("damage-base.gsnp");
    engine.save_snapshot(&path).unwrap();
    let pristine = std::fs::read(&path).unwrap();

    // payload corruption → CRC error (byte 70 sits inside the first
    // payload, the global config JSON at offset 64)
    let corrupt = tmp("damage-crc.gsnp");
    let mut bytes = pristine.clone();
    bytes[70] ^= 0xA5;
    std::fs::write(&corrupt, &bytes).unwrap();
    let err = Engine::builder().from_snapshot(&corrupt).unwrap_err().to_string();
    assert!(err.to_lowercase().contains("crc"), "{err}");
    // ...but inspect still reports the damage instead of dying
    let info = snapshot::inspect(&corrupt).unwrap();
    assert!(!info.intact());

    // truncation → length error
    let short = tmp("damage-short.gsnp");
    std::fs::write(&short, &pristine[..pristine.len() - 21]).unwrap();
    let err = Engine::builder().from_snapshot(&short).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");

    // version bump → unsupported-version error
    let vbump = tmp("damage-version.gsnp");
    let mut bytes = pristine.clone();
    bytes[4] = 0x7F;
    std::fs::write(&vbump, &bytes).unwrap();
    let err = Engine::builder().from_snapshot(&vbump).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");

    // wrong magic → immediate rejection
    let magic = tmp("damage-magic.gsnp");
    let mut bytes = pristine;
    bytes[0..4].copy_from_slice(b"NOPE");
    std::fs::write(&magic, &bytes).unwrap();
    assert!(Engine::builder().from_snapshot(&magic).is_err());
}

#[test]
fn coordinator_checkpoint_and_warm_start_serve_identically() {
    let k = 8;
    let dir = tmp("ckpt-dir");
    let cfg = ServeConfig {
        k,
        kappa: 5,
        schema: SchemaConfig::TernaryParseTree,
        max_batch: 8,
        max_wait_us: 200,
        shards: 2,
        queue_cap: 64,
        use_xla: false,
        artifacts_dir: "artifacts".into(),
        threshold: 0.0,
        checkpoint: Some(CheckpointConfig {
            dir: dir.clone(),
            every_ms: 10_000, // periodic timer will not fire; rely on the
            keep_last: 2,     // final checkpoint at shutdown
        }),
        ..ServeConfig::default()
    };
    let coord = Coordinator::start(
        cfg.clone(),
        items(200, k, 8),
        cpu_scorer_factory(),
    )
    .unwrap();
    coord.remove(11).unwrap();
    let extra = users(1, k, 9).pop().unwrap();
    coord.upsert(200, &extra).unwrap();
    let version = coord.version();
    let probe_users = users(6, k, 10);
    let want: Vec<_> = probe_users
        .iter()
        .map(|u| coord.submit(u.clone(), 5).unwrap())
        .collect();
    coord.shutdown(); // final checkpoint fires here

    let latest = snapshot::latest_snapshot(&dir).unwrap().expect("checkpoint");
    let warm =
        Coordinator::start_from_snapshot(cfg, &latest, cpu_scorer_factory())
            .unwrap();
    assert_eq!(warm.version(), version);
    assert_eq!(warm.total_items(), 201);
    for (u, w) in probe_users.iter().zip(&want) {
        let got = warm.submit(u.clone(), 5).unwrap();
        assert_eq!(got.candidates, w.candidates);
        assert_eq!(
            got.results.iter().map(|s| (s.id, s.score.to_bits())).collect::<Vec<_>>(),
            w.results.iter().map(|s| (s.id, s.score.to_bits())).collect::<Vec<_>>(),
        );
        assert!(got.results.iter().all(|s| s.id != 11), "tombstone leaked");
    }
    warm.shutdown();
}

/// The compressed tier round-trips without requantising: a quantized +
/// packed engine (with pending mutation state) loads back byte-exact —
/// candidates, ids, and scores — and the loaded engine keeps mutating;
/// an old reader's format gate is exercised via the version stamp.
#[test]
fn quantized_packed_engine_roundtrips_byte_exact() {
    use geomap::configx::{PostingsMode, QuantMode, SchemaConfig};
    let k = 16;
    let mut built = Engine::builder()
        .schema(SchemaConfig::TernaryOneHot)
        .threshold(0.5)
        .quant(QuantMode::Int8 { refine: 4 })
        .postings(PostingsMode::Packed)
        .mutation(MutationConfig { max_delta: 0 })
        .build(items(250, k, 40))
        .unwrap();
    // leave delta + tombstone state pending so every section is non-trivial
    let f = users(1, k, 41).pop().unwrap();
    built.upsert(11, &f).unwrap();
    built.upsert(250, &f).unwrap();
    built.remove(42).unwrap();

    let path = tmp("quant-packed.gsnp");
    built.save_snapshot(&path).unwrap();

    // the container self-describes as format v2 with both new sections
    let info = snapshot::inspect(&path).unwrap();
    assert_eq!(info.format_version, 2);
    let kinds: Vec<&str> =
        info.sections.iter().map(|s| s.kind.as_str()).collect();
    assert!(kinds.contains(&"quant") && kinds.contains(&"packed-index"));
    assert!(!info.compression.is_empty());

    let mut loaded = Engine::builder().from_snapshot(&path).unwrap();
    assert!(loaded.spec().same_spec(&built.spec()));
    assert!(loaded.quant_store().is_some(), "tier must load, not rebuild");
    let (sb, sl) = (built.stats(), loaded.stats());
    assert_eq!(sl.memory_bytes, sb.memory_bytes, "scan tier bytes drifted");
    assert_eq!(sl.refine_bytes, sb.refine_bytes);
    assert_identical(&built, &loaded, k, 400);

    // and the loaded engine keeps mutating through both tiers
    built.merge().unwrap();
    loaded.merge().unwrap();
    assert_identical(&built, &loaded, k, 500);
    let g = users(1, k, 42).pop().unwrap();
    built.upsert(100, &g).unwrap();
    loaded.upsert(100, &g).unwrap();
    assert_identical(&built, &loaded, k, 600);
}

/// Explicit quant/postings builder overrides conflict with a snapshot's
/// recorded spec by error, never silently.
#[test]
fn quant_and_postings_overrides_conflict_by_error() {
    use geomap::configx::{PostingsMode, QuantMode};
    let engine = Engine::builder()
        .quant(QuantMode::Int8 { refine: 4 })
        .build(items(60, 8, 43))
        .unwrap();
    let path = tmp("quant-conflict.gsnp");
    engine.save_snapshot(&path).unwrap();
    let err = Engine::builder()
        .quant(QuantMode::Off)
        .from_snapshot(&path)
        .unwrap_err()
        .to_string();
    assert!(err.contains("quant"), "{err}");
    let err = Engine::builder()
        .postings(PostingsMode::Packed)
        .from_snapshot(&path)
        .unwrap_err()
        .to_string();
    assert!(err.contains("postings"), "{err}");
    // untouched defaults defer to the snapshot
    let loaded = Engine::builder().from_snapshot(&path).unwrap();
    assert!(loaded.quant_store().is_some());
}

/// A sharded coordinator serving the compressed tier warm-starts from
/// its checkpoint with identical responses (the cpu scorer drives the
/// quantized rescore path end to end).
#[test]
fn quantized_coordinator_warm_starts_identically() {
    use geomap::configx::{PostingsMode, QuantMode, SchemaConfig};
    let k = 16;
    let mut cfg = ServeConfig {
        k,
        kappa: 6,
        max_batch: 8,
        max_wait_us: 200,
        shards: 2,
        queue_cap: 256,
        use_xla: false,
        threshold: 0.5,
        schema: SchemaConfig::TernaryOneHot,
        ..ServeConfig::default()
    };
    cfg.quant = QuantMode::Int8 { refine: 4 };
    cfg.postings = PostingsMode::Packed;
    let coord = Coordinator::start(
        cfg.clone(),
        items(220, k, 44),
        cpu_scorer_factory(),
    )
    .unwrap();
    coord.remove(13).unwrap();
    let f = users(1, k, 45).pop().unwrap();
    coord.upsert(220, &f).unwrap();
    let path = tmp("quant-coord.gsnp");
    let saved = coord.save_snapshot(&path).unwrap();

    let probes = users(8, k, 46);
    let want: Vec<_> = probes
        .iter()
        .map(|u| coord.submit(u.clone(), 6).unwrap())
        .collect();
    coord.shutdown();

    let warm =
        Coordinator::start_from_snapshot(cfg, &path, cpu_scorer_factory())
            .unwrap();
    assert_eq!(warm.version(), saved);
    for (u, w) in probes.iter().zip(&want) {
        let got = warm.submit(u.clone(), 6).unwrap();
        assert_eq!(got.candidates, w.candidates);
        assert_eq!(
            got.results.iter().map(|s| (s.id, s.score)).collect::<Vec<_>>(),
            w.results.iter().map(|s| (s.id, s.score)).collect::<Vec<_>>(),
            "quantized warm start must serve byte-identical results"
        );
    }
    warm.shutdown();
}
