//! Integration tests across the AOT boundary: artifacts built by
//! `python/compile/aot.py` (L2 jax + L1 pallas) loaded and executed by
//! the rust runtime (L3) on the PJRT CPU client.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use geomap::linalg::Matrix;
use geomap::rng::Rng;
use geomap::runtime::{
    verify_goldens, CpuScorer, Kind, Scorer, XlaRuntime, XlaScorer,
};
use geomap::tessellation::{TernaryTessellation, Tessellation};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn golden_cases_all_match() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = XlaRuntime::load("artifacts").unwrap();
    let checked = verify_goldens(&rt).unwrap();
    assert!(checked >= 8, "expected >=8 golden cases, got {checked}");
}

#[test]
fn xla_scorer_matches_cpu_scorer_padded_path() {
    if !artifacts_available() {
        return;
    }
    let xla = XlaScorer::load("artifacts").unwrap();
    let mut rng = Rng::seeded(11);
    // deliberately ragged shapes so the runtime must pad (B=5 < 8, T=700 < 1024)
    let users = Matrix::gaussian(&mut rng, 5, 16, 1.0);
    let items = Matrix::gaussian(&mut rng, 700, 16, 1.0);
    let a = xla.score(&users, &items).unwrap();
    let b = CpuScorer.score(&users, &items).unwrap();
    assert_eq!(a.rows(), 5);
    assert_eq!(a.cols(), 700);
    for r in 0..5 {
        for c in 0..700 {
            assert!(
                (a.get(r, c) - b.get(r, c)).abs() < 1e-3,
                "({r},{c}): {} vs {}",
                a.get(r, c),
                b.get(r, c)
            );
        }
    }
}

#[test]
fn xla_scorer_matches_cpu_scorer_tiled_path() {
    if !artifacts_available() {
        return;
    }
    let xla = XlaScorer::load("artifacts").unwrap();
    let mut rng = Rng::seeded(13);
    // larger than any single artifact tile: forces the (B,T) tiling loop
    let users = Matrix::gaussian(&mut rng, 40, 32, 1.0);
    let items = Matrix::gaussian(&mut rng, 3000, 32, 1.0);
    let a = xla.score(&users, &items).unwrap();
    let b = CpuScorer.score(&users, &items).unwrap();
    let mut max_err = 0.0f32;
    for (x, y) in a.as_slice().iter().zip(b.as_slice()) {
        max_err = max_err.max((x - y).abs());
    }
    assert!(max_err < 1e-3, "max abs err {max_err}");
}

#[test]
fn xla_topk_matches_cpu_topk() {
    if !artifacts_available() {
        return;
    }
    let xla = XlaScorer::load("artifacts").unwrap();
    let mut rng = Rng::seeded(17);
    for (b, t, k) in [(8, 1024, 16), (3, 500, 16), (32, 2048, 32)] {
        let users = Matrix::gaussian(&mut rng, b, k, 1.0);
        let items = Matrix::gaussian(&mut rng, t, k, 1.0);
        let xr = xla.score_topk(&users, &items, 10).unwrap();
        let cr = CpuScorer.score_topk(&users, &items, 10).unwrap();
        assert_eq!(xr.len(), b);
        for (row_x, row_c) in xr.iter().zip(&cr) {
            assert_eq!(row_x.len(), row_c.len());
            for (x, c) in row_x.iter().zip(row_c) {
                // ids may differ on exact ties; scores must agree
                assert!(
                    (x.1 - c.1).abs() < 1e-3,
                    "score {} vs {} (B={b},T={t})",
                    x.1,
                    c.1
                );
            }
        }
    }
}

#[test]
fn jax_tessellation_agrees_with_rust_algorithm2() {
    // cross-layer check: the L2 jax implementation of Algorithm 2
    // (tess_ternary artifact) and the independent rust implementation
    // must produce the same tessellating vectors.
    if !artifacts_available() {
        return;
    }
    let rt = XlaRuntime::load("artifacts").unwrap();
    let entry = rt
        .manifest
        .of_kind(Kind::TessTernary)
        .find(|e| e.meta.k == 16)
        .expect("tess_ternary_k16 artifact")
        .name
        .clone();
    let module = rt.module(&entry).unwrap();
    let (n, k) = (module.entry.meta.n, module.entry.meta.k);

    let mut rng = Rng::seeded(23);
    let z = Matrix::gaussian(&mut rng, n, k, 1.0);
    let outs = module.run_f32(&[z.as_slice()]).unwrap();
    let jax_a = outs[0].to_vec::<f32>().unwrap();

    let tess = TernaryTessellation::new(k);
    for r in 0..n {
        let rust_a = tess.assign(z.row(r)).to_unit();
        for j in 0..k {
            let jx = jax_a[r * k + j];
            assert!(
                (jx - rust_a[j]).abs() < 1e-5,
                "row {r} coord {j}: jax {jx} vs rust {}",
                rust_a[j]
            );
        }
    }
}

#[test]
fn module_cache_compiles_once() {
    if !artifacts_available() {
        return;
    }
    let rt = XlaRuntime::load("artifacts").unwrap();
    let name = &rt.manifest.entries[0].name.clone();
    let a = rt.module(name).unwrap();
    let b = rt.module(name).unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
    assert_eq!(rt.compiled_count(), 1);
}

#[test]
fn bad_input_shapes_are_rejected() {
    if !artifacts_available() {
        return;
    }
    let rt = XlaRuntime::load("artifacts").unwrap();
    let name = rt.manifest.entries[0].name.clone();
    let module = rt.module(&name).unwrap();
    let wrong = vec![0.0f32; 3];
    assert!(module.run_f32(&[&wrong]).is_err());
    assert!(module.run_f32(&[]).is_err());
}

#[test]
fn xla_masked_scoring_matches_host_masking() {
    if !artifacts_available() {
        return;
    }
    let xla = XlaScorer::load("artifacts").unwrap();
    let mut rng = Rng::seeded(31);
    // ragged shapes force padding + tiling of the masked artifact
    let users = Matrix::gaussian(&mut rng, 5, 16, 1.0);
    let items = Matrix::gaussian(&mut rng, 1500, 16, 1.0);
    let mask: Vec<f32> = (0..1500)
        .map(|i| if (i * 7) % 3 == 0 { 1.0 } else { 0.0 })
        .collect();
    let a = xla.score_masked(&users, &items, &mask).unwrap();
    // reference: CPU default (score + host-side mask)
    let b = CpuScorer.score_masked(&users, &items, &mask).unwrap();
    for r in 0..5 {
        for c in 0..1500 {
            if mask[c] == 0.0 {
                assert!(
                    a.get(r, c) <= geomap::runtime::MASKED_SCORE / 2.0,
                    "({r},{c}) should be masked: {}",
                    a.get(r, c)
                );
            } else {
                assert!(
                    (a.get(r, c) - b.get(r, c)).abs() < 1e-3,
                    "({r},{c}): {} vs {}",
                    a.get(r, c),
                    b.get(r, c)
                );
            }
        }
    }
}

#[test]
fn masked_mask_length_is_validated() {
    if !artifacts_available() {
        return;
    }
    let xla = XlaScorer::load("artifacts").unwrap();
    let mut rng = Rng::seeded(33);
    let users = Matrix::gaussian(&mut rng, 2, 16, 1.0);
    let items = Matrix::gaussian(&mut rng, 10, 16, 1.0);
    assert!(xla.score_masked(&users, &items, &[1.0; 3]).is_err());
    assert!(CpuScorer.score_masked(&users, &items, &[1.0; 3]).is_err());
}
